//! The message fabric: rank endpoints, point-to-point send/recv, logical
//! clock accounting, and communication statistics.
//!
//! Since PR 7 the fabric is a **bounded, fallible** transport
//! (DESIGN.md §16):
//!
//! - **Credit-based flow control** — every `(src, dst)` link carries at
//!   most `cap` in-flight bytes, where `cap` is the minimum over the
//!   link's hops of the per-[`LinkKind`] caps in [`CommTuning`].
//!   Senders block (or report [`TrySend::Full`]) when credit is
//!   exhausted; credit returns when the *receiver consumes* the
//!   message, not when it is enqueued — an out-of-order stash therefore
//!   holds credit and cannot grow past the cap. A message larger than
//!   the cap is admitted only when its link is idle, so oversized
//!   collective payloads make progress instead of deadlocking.
//! - **Fallible API** — send/recv return [`AkResult`]; every blocking
//!   wait carries a deadline and surfaces
//!   [`AkError::CommTimeout`], and a dead peer surfaces as
//!   [`AkError::RankDead`] with rank attribution instead of the old
//!   cross-thread `.expect()` panic.
//! - **Fault injection** — an optional [`FaultState`]
//!   (see [`super::fault`]) drops, delays, or partitions links and
//!   kills or stalls ranks at deterministic message boundaries; the
//!   `comm.send` / `comm.recv` [`crate::util::failpoint`] hooks compose
//!   with it.
//! - **Coordinated abort** — a rank that dies (kill fault, panic, or a
//!   fatal comm error) trips an epoch-tagged abort flag on drop; every
//!   blocked survivor wakes with `RankDead` so the driver can join all
//!   threads, then restart and resume the job ([`FabricCtl::abort_all`]
//!   is the watchdog's handle on the same mechanism).

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use crate::cfg::TransferMode;
use crate::cluster::{ClusterSpec, LinkKind, SimClocks};
use crate::dtype::SortKey;
use crate::obs;
use crate::session::{AkError, AkResult};
use crate::util::failpoint;

use super::fault::{FaultState, OpFault, RetryPolicy, SendFault};
use super::hb::{HbState, VClock, Wait};
use super::wire::{bytes_to_vec, vec_to_bytes};

/// One in-flight message.
struct Msg {
    src: usize,
    tag: u64,
    bytes: Vec<u8>,
    /// Simulated arrival time at the destination.
    arrive: f64,
    /// Bytes charged against the link's credit (0 for self-sends).
    charged: usize,
    /// The link kinds this message's credit is charged on (empty for
    /// self-sends); consumption returns the per-kind in-flight bytes
    /// the observability counter tracks sample.
    hops: Vec<LinkKind>,
    /// Happens-before stamp (vector clock, channel sequence number);
    /// `None` unless [`CommTuning::hb_check`] is on.
    stamp: Option<(VClock, u64)>,
}

/// Tuning knobs of the bounded fabric (derived from `[comm]` config by
/// the driver; [`Default`] gives generous caps and deadlines suitable
/// for fault-free runs).
#[derive(Clone, Debug)]
pub struct CommTuning {
    /// In-flight byte cap per NVLink hop.
    pub cap_nvlink: usize,
    /// In-flight byte cap per InfiniBand hop.
    pub cap_ib: usize,
    /// In-flight byte cap per PCIe hop.
    pub cap_pcie: usize,
    /// In-flight byte cap per host-memory hop.
    pub cap_hostmem: usize,
    /// Deadline of every blocking receive / barrier (wall seconds).
    pub recv_timeout_secs: f64,
    /// Deadline of a credit-blocked send (wall seconds).
    pub send_timeout_secs: f64,
    /// Sender-side retry policy for retryable comm timeouts.
    pub retry: RetryPolicy,
    /// Deterministic fault injection (shared across restart attempts).
    pub faults: Option<Arc<FaultState>>,
    /// Coordinated-abort epoch (the driver's restart-attempt index).
    pub epoch: u64,
    /// Happens-before / deadlock detector debug mode (DESIGN.md §17):
    /// vector clocks on every message, per-`(src, dst, tag)` delivery
    /// monotonicity checks, and a wait-for graph over credit waits,
    /// recv waits, barriers, and the compute token that diagnoses a
    /// deadlock as a named cycle ([`AkError::Deadlock`]) the moment it
    /// closes — instead of a watchdog timeout.
    pub hb_check: bool,
}

impl Default for CommTuning {
    fn default() -> CommTuning {
        CommTuning {
            cap_nvlink: 64 << 20,
            cap_ib: 64 << 20,
            cap_pcie: 64 << 20,
            cap_hostmem: 64 << 20,
            recv_timeout_secs: 600.0,
            send_timeout_secs: 600.0,
            retry: RetryPolicy::default(),
            faults: None,
            epoch: 0,
            hb_check: false,
        }
    }
}

impl CommTuning {
    fn cap(&self, kind: LinkKind) -> usize {
        match kind {
            LinkKind::NvLink => self.cap_nvlink,
            LinkKind::Infiniband => self.cap_ib,
            LinkKind::PcieD2H => self.cap_pcie,
            LinkKind::HostMem => self.cap_hostmem,
        }
    }
}

/// Fault/flow counters extracted from [`CommStats`] for records and
/// bench reports (aggregatable across driver restart attempts).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FaultCounters {
    /// Sends that blocked at least once on exhausted link credit.
    pub credit_stalls: u64,
    /// Sender-side retries after a retryable comm timeout.
    pub retries: u64,
    /// Operations that gave up at a deadline (or saw a fault drop).
    pub timeouts: u64,
    /// Messages eaten by injected link faults.
    pub dropped: u64,
}

impl FaultCounters {
    /// Element-wise accumulate (the driver sums attempts).
    pub fn add(&mut self, o: FaultCounters) {
        self.credit_stalls += o.credit_stalls;
        self.retries += o.retries;
        self.timeouts += o.timeouts;
        self.dropped += o.dropped;
    }

    /// True when any fault-path counter is non-zero (CI smoke gate).
    pub fn any_faults(&self) -> bool {
        self.retries > 0 || self.timeouts > 0 || self.dropped > 0
    }

    /// The registry form of these counters
    /// ([`crate::obs::FABRIC_COUNTERS`]); `recoveries` is driver-owned
    /// (restart attempts) and enters as given.
    pub fn snapshot_with_recoveries(&self, recoveries: u64) -> obs::CounterSnapshot {
        let mut s = obs::CounterSnapshot::new();
        s.push("credit_stalls", self.credit_stalls);
        s.push("retries", self.retries);
        s.push("timeouts", self.timeouts);
        s.push("dropped", self.dropped);
        s.push("recoveries", recoveries);
        s
    }
}

/// Cumulative fabric statistics (shared across ranks).
#[derive(Debug, Default)]
pub struct CommStats {
    pub messages: AtomicU64,
    pub bytes: AtomicU64,
    pub nvlink_bytes: AtomicU64,
    pub ib_bytes: AtomicU64,
    pub pcie_bytes: AtomicU64,
    pub hostmem_bytes: AtomicU64,
    /// Sends that blocked at least once on exhausted link credit.
    pub credit_stalls: AtomicU64,
    /// Sender-side retries after a retryable comm timeout.
    pub retries: AtomicU64,
    /// Operations that gave up at a deadline (or saw a fault drop).
    pub timeouts: AtomicU64,
    /// Messages eaten by injected link faults.
    pub dropped: AtomicU64,
    /// Messages delivered with injected extra latency.
    pub delayed: AtomicU64,
    /// Peak in-flight bytes observed on any single link (proves the
    /// credit cap held — the flow-control proptest reads this).
    pub peak_link_bytes: AtomicU64,
}

impl CommStats {
    fn record(&self, hops: &[LinkKind], bytes: usize) {
        self.messages.fetch_add(1, Ordering::Relaxed);
        self.bytes.fetch_add(bytes as u64, Ordering::Relaxed);
        for h in hops {
            let slot = match h {
                LinkKind::NvLink => &self.nvlink_bytes,
                LinkKind::Infiniband => &self.ib_bytes,
                LinkKind::PcieD2H => &self.pcie_bytes,
                LinkKind::HostMem => &self.hostmem_bytes,
            };
            slot.fetch_add(bytes as u64, Ordering::Relaxed);
        }
    }

    fn note_peak(&self, in_flight: usize) {
        self.peak_link_bytes.fetch_max(in_flight as u64, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> (u64, u64) {
        (self.messages.load(Ordering::Relaxed), self.bytes.load(Ordering::Relaxed))
    }

    /// The fault/flow counters (see [`FaultCounters`]).
    pub fn fault_counters(&self) -> FaultCounters {
        FaultCounters {
            credit_stalls: self.credit_stalls.load(Ordering::Relaxed),
            retries: self.retries.load(Ordering::Relaxed),
            timeouts: self.timeouts.load(Ordering::Relaxed),
            dropped: self.dropped.load(Ordering::Relaxed),
        }
    }
}

/// Coordinated-abort marker: which rank died, in which epoch.
#[derive(Clone, Copy, Debug)]
struct Abort {
    rank: usize,
    epoch: u64,
}

/// Everything the condvar guards: inboxes, credit ledger, liveness,
/// abort flag, barrier generation, and per-rank phase notes.
struct State {
    /// Per-destination inbox (FIFO per link by construction: a sender
    /// appends its link's messages in program order under the lock).
    inboxes: Vec<VecDeque<Msg>>,
    /// In-flight (sent, not yet consumed) bytes per `src * p + dst`.
    in_flight: Vec<usize>,
    /// Simulated time at which each link last returned credit; a
    /// sender that stalled resumes no earlier than this.
    release_clock: Vec<f64>,
    /// False once a rank's endpoint dropped.
    alive: Vec<bool>,
    /// Set when a rank died *with failure* (or the watchdog fired).
    abort: Option<Abort>,
    /// Barrier generation counter + arrivals this generation.
    bar_gen: u64,
    bar_arrived: usize,
    /// Last phase note per rank (watchdog diagnostics).
    phases: Vec<&'static str>,
    /// Happens-before / deadlock detector ([`CommTuning::hb_check`]).
    hb: Option<HbState>,
    /// In-flight bytes summed per [`LinkKind`] (indexed by
    /// [`kind_slot`]); sampled into the observability counter tracks so
    /// NVLink-vs-PCIe saturation is visible on the trace timeline.
    kind_in_flight: [usize; 4],
}

/// Index of a link kind in [`State::kind_in_flight`].
fn kind_slot(k: LinkKind) -> usize {
    match k {
        LinkKind::NvLink => 0,
        LinkKind::Infiniband => 1,
        LinkKind::PcieD2H => 2,
        LinkKind::HostMem => 3,
    }
}

/// Counter-track name of a link kind's in-flight bytes.
fn inflight_track(k: LinkKind) -> &'static str {
    match k {
        LinkKind::NvLink => "inflight.nvlink",
        LinkKind::Infiniband => "inflight.ib",
        LinkKind::PcieD2H => "inflight.pcie",
        LinkKind::HostMem => "inflight.hostmem",
    }
}

/// Maintain the per-kind in-flight totals for a charge (`add`) or a
/// release, sampling each touched kind's counter track. The totals are
/// kept unconditionally (plain adds under the already-held state lock);
/// the samples are inert unless tracing is armed.
fn track_kind_inflight(st: &mut State, hops: &[LinkKind], add: bool, len: usize) {
    for &k in hops {
        let s = kind_slot(k);
        if add {
            st.kind_in_flight[s] += len;
        } else {
            st.kind_in_flight[s] = st.kind_in_flight[s].saturating_sub(len);
        }
        obs::counter(inflight_track(k), st.kind_in_flight[s] as u64);
    }
}

struct Shared {
    spec: ClusterSpec,
    mode: TransferMode,
    clocks: SimClocks,
    stats: CommStats,
    /// Per-rank: does this rank host a device (GPU) or is it a CPU rank?
    device: Vec<bool>,
    tuning: CommTuning,
    state: Mutex<State>,
    cv: Condvar,
    /// Compute token: measured-compute sections run one at a time so the
    /// wall time a rank observes is its own work, not oversubscription
    /// noise from the other rank threads sharing this host's cores.
    /// Logical clocks make the serialisation invisible in simulated time.
    compute: Mutex<()>,
}

impl Shared {
    /// Lock the state, surviving a poisoned mutex (a rank thread that
    /// panicked mid-op must not take the whole fabric down with it).
    fn lock(&self) -> MutexGuard<'_, State> {
        self.state.lock().unwrap_or_else(|e| e.into_inner())
    }
}

/// Builder for a set of connected [`Endpoint`]s.
pub struct Fabric;

impl Fabric {
    /// Create `ranks` endpoints with default [`CommTuning`]. `device[r]`
    /// marks device ranks (affects link selection and the device model);
    /// pass all-true for GPU runs, all-false for the "CC-JB" CPU
    /// algorithm, or a mix for co-sorting.
    pub fn new(spec: ClusterSpec, mode: TransferMode, device: Vec<bool>) -> Vec<Endpoint> {
        Fabric::new_with(spec, mode, device, CommTuning::default())
    }

    /// [`Fabric::new`] with explicit tuning (credit caps, deadlines,
    /// retry policy, fault injection, abort epoch).
    pub fn new_with(
        spec: ClusterSpec,
        mode: TransferMode,
        device: Vec<bool>,
        tuning: CommTuning,
    ) -> Vec<Endpoint> {
        let ranks = device.len();
        assert!(ranks > 0);
        let hb = tuning.hb_check.then(|| HbState::new(ranks));
        let shared = Arc::new(Shared {
            spec,
            mode,
            clocks: SimClocks::new(ranks),
            stats: CommStats::default(),
            device,
            tuning,
            state: Mutex::new(State {
                inboxes: (0..ranks).map(|_| VecDeque::new()).collect(),
                in_flight: vec![0; ranks * ranks],
                release_clock: vec![0.0; ranks * ranks],
                alive: vec![true; ranks],
                abort: None,
                bar_gen: 0,
                bar_arrived: 0,
                phases: vec!["start"; ranks],
                hb,
                kind_in_flight: [0; 4],
            }),
            cv: Condvar::new(),
            compute: Mutex::new(()),
        });
        (0..ranks)
            .map(|rank| Endpoint {
                rank,
                nranks: ranks,
                shared: shared.clone(),
                pending: HashMap::new(),
                stashed: 0,
                coll_seq: 0,
                phase: "start",
                failed: false,
                finished: false,
            })
            .collect()
    }
}

/// Per-rank snapshot for watchdog / abort diagnostics.
#[derive(Clone, Copy, Debug)]
pub struct RankDiag {
    /// The rank.
    pub rank: usize,
    /// Its last phase note (see [`Endpoint::note_phase`]).
    pub phase: &'static str,
    /// Its simulated clock.
    pub clock: f64,
    /// Whether its endpoint is still alive.
    pub alive: bool,
}

/// Driver-side handle on a fabric: coordinated abort + diagnostics
/// without owning any rank's [`Endpoint`].
#[derive(Clone)]
pub struct FabricCtl {
    shared: Arc<Shared>,
}

impl FabricCtl {
    /// Trip the coordinated abort, blaming `rank`: every blocked fabric
    /// wait (send credit, recv, barrier, injected stall) wakes with
    /// [`AkError::RankDead`] so the driver can join all rank threads.
    pub fn abort_all(&self, rank: usize) {
        let mut st = self.shared.lock();
        if st.abort.is_none() {
            st.abort = Some(Abort { rank, epoch: self.shared.tuning.epoch });
        }
        self.shared.cv.notify_all();
    }

    /// Last-known per-rank phase notes, clocks, and liveness.
    pub fn diagnostics(&self) -> Vec<RankDiag> {
        let st = self.shared.lock();
        (0..st.phases.len())
            .map(|r| RankDiag {
                rank: r,
                phase: st.phases[r],
                clock: self.shared.clocks.get(r),
                alive: st.alive[r],
            })
            .collect()
    }

    /// One line per rank, for embedding in a watchdog error.
    pub fn diag_table(&self) -> String {
        self.diagnostics()
            .iter()
            .map(|d| {
                format!(
                    "rank {}: phase={} clock={:.6}s {}",
                    d.rank,
                    d.phase,
                    d.clock,
                    if d.alive { "alive" } else { "dead" }
                )
            })
            .collect::<Vec<_>>()
            .join("; ")
    }

    /// The fabric's shared statistics.
    pub fn stats(&self) -> &CommStats {
        &self.shared.stats
    }

    /// Ranks that have not noted completion (`phase != "done"`); the
    /// watchdog blames the first of these.
    pub fn unfinished_ranks(&self) -> Vec<usize> {
        let st = self.shared.lock();
        (0..st.phases.len()).filter(|&r| st.phases[r] != "done").collect()
    }
}

/// Outcome of a non-blocking send attempt.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TrySend {
    /// Enqueued.
    Sent,
    /// The link's credit is exhausted; try again after
    /// [`Endpoint::wait_activity`].
    Full,
}

/// A rank's handle on the fabric. Not `Clone`: exactly one per rank.
pub struct Endpoint {
    rank: usize,
    nranks: usize,
    shared: Arc<Shared>,
    /// Out-of-order stash: messages received before they were asked
    /// for. Stashed messages still hold their link credit (released on
    /// consumption), so the stash is bounded by the sum of link caps.
    pending: HashMap<(usize, u64), VecDeque<Msg>>,
    /// Bytes currently held in `pending` (diagnostics / tests).
    stashed: usize,
    /// Collective sequence number (advances identically on all ranks).
    pub(super) coll_seq: u64,
    /// Current phase note (fault scoping + watchdog diagnostics).
    phase: &'static str,
    /// A fatal comm error surfaced through this endpoint; its drop
    /// trips the coordinated abort.
    failed: bool,
    /// The rank completed cleanly; its drop is not a death.
    finished: bool,
}

impl Endpoint {
    pub fn rank(&self) -> usize {
        self.rank
    }

    pub fn nranks(&self) -> usize {
        self.nranks
    }

    pub fn is_device(&self) -> bool {
        self.shared.device[self.rank]
    }

    pub fn spec(&self) -> &ClusterSpec {
        &self.shared.spec
    }

    pub fn mode(&self) -> TransferMode {
        self.shared.mode
    }

    pub fn stats(&self) -> &CommStats {
        &self.shared.stats
    }

    /// The active retry policy (collectives and the exchange share it).
    pub fn retry_policy(&self) -> RetryPolicy {
        self.shared.tuning.retry.clone()
    }

    /// The blocking-receive deadline, as a [`Duration`].
    pub fn recv_timeout(&self) -> Duration {
        Duration::from_secs_f64(self.shared.tuning.recv_timeout_secs.max(1e-3))
    }

    /// A driver-side control handle on this endpoint's fabric.
    pub fn ctl(&self) -> FabricCtl {
        FabricCtl { shared: self.shared.clone() }
    }

    /// Current simulated time of this rank.
    pub fn now(&self) -> f64 {
        self.shared.clocks.get(self.rank)
    }

    /// Advance this rank's simulated clock (compute accounting; callers
    /// convert measured time through `cluster::DeviceModel` first).
    pub fn advance(&self, dt: f64) {
        self.shared.clocks.advance(self.rank, dt);
    }

    /// Run a measured-compute section under the fabric's compute token:
    /// returns (result, accurate wall seconds). MUST NOT communicate
    /// inside `f` (the token would serialise against other ranks' compute
    /// and deadlock a collective).
    ///
    /// Lock order is compute-then-state only (the state mutex is never
    /// held while acquiring the token), so the two locks cannot invert.
    pub fn measured<R>(&self, f: impl FnOnce() -> R) -> (R, f64) {
        let hb_on = self.shared.tuning.hb_check;
        if hb_on {
            // Register intent before blocking on the token. The token
            // holder never parks in the fabric (the contract above), so
            // this registration cannot close a cycle itself — but peer
            // registrations must see through ranks queued here.
            let mut st = self.shared.lock();
            let State { hb, phases, .. } = &mut *st;
            if let Some(hb) = hb.as_mut() {
                hb.register_wait(self.rank, Wait::Compute, phases);
            }
        }
        let token = self.shared.compute.lock().unwrap_or_else(|e| e.into_inner());
        if hb_on {
            let mut st = self.shared.lock();
            if let Some(hb) = st.hb.as_mut() {
                hb.clear_wait(self.rank);
                hb.set_compute_holder(Some(self.rank));
            }
        }
        let t0 = Instant::now();
        let r = f();
        let dt = t0.elapsed().as_secs_f64();
        if hb_on {
            // Clear the holder BEFORE releasing the token so the next
            // holder's set cannot be clobbered by this rank's clear.
            let mut st = self.shared.lock();
            if let Some(hb) = st.hb.as_mut() {
                if hb.compute_holder() == Some(self.rank) {
                    hb.set_compute_holder(None);
                }
            }
        }
        drop(token);
        (r, dt)
    }

    /// Record the rank's current phase ("local-sort", "splitters",
    /// "exchange", "final", "done"): scopes phase-targeted fault rules
    /// and feeds the watchdog's per-rank diagnostics.
    pub fn note_phase(&mut self, phase: &'static str) {
        self.phase = phase;
        // Drive the per-rank phase track of the trace timeline from the
        // same notes the watchdog reads — every pipeline that reports
        // phases gets spans for free (DESIGN.md §18).
        if obs::enabled() {
            obs::set_thread_label(&format!("rank {}", self.rank));
            if phase == "done" {
                obs::phase_end();
            } else {
                obs::phase(phase);
            }
        }
        let mut st = self.shared.lock();
        st.phases[self.rank] = phase;
    }

    /// Mark clean completion: the endpoint's drop will not be treated
    /// as a rank death. Called at the end of a rank's pipeline, after
    /// the final barrier.
    pub fn finish(&mut self) {
        self.finished = true;
        self.note_phase("done");
    }

    /// Mark this endpoint failed and return the error (its drop will
    /// trip the coordinated abort so peers unblock promptly).
    fn fatal<T>(&mut self, e: AkError) -> AkResult<T> {
        self.failed = true;
        Err(e)
    }

    fn rank_dead(&mut self, a: Abort) -> AkError {
        self.failed = true;
        AkError::RankDead { rank: a.rank, epoch: a.epoch }
    }

    /// Build (and count) a timeout error; `fatal` decides whether it
    /// poisons the endpoint (receiver deadlines do, retryable sender
    /// timeouts don't).
    fn timeout_err(
        &mut self,
        op: &'static str,
        peer: Option<usize>,
        waited: Duration,
        detail: String,
        fatal: bool,
    ) -> AkError {
        self.shared.stats.timeouts.fetch_add(1, Ordering::Relaxed);
        if fatal {
            self.failed = true;
        }
        AkError::CommTimeout {
            op,
            rank: self.rank,
            peer,
            waited_secs: waited.as_secs_f64(),
            detail,
        }
    }

    /// Public form of [`Self::timeout_err`] for callers that implement
    /// their own progress deadline over `try_send`/`try_recv_any` (the
    /// streamed exchange).
    pub fn deadline_exceeded(
        &mut self,
        op: &'static str,
        waited: Duration,
        detail: String,
    ) -> AkError {
        self.timeout_err(op, None, waited, detail, true)
    }

    /// Every fabric op passes through here: failpoint hooks compose
    /// with the seeded fault plan's kill/stall rules (a "message
    /// boundary" in the fault grammar is one of these checks).
    fn op_boundary(&mut self, op: &'static str) -> AkResult<()> {
        failpoint::check(if op == "send" { "comm.send" } else { "comm.recv" })
            .map_err(AkError::Internal)?;
        let Some(faults) = self.shared.tuning.faults.clone() else {
            return Ok(());
        };
        match faults.on_op(self.rank, self.phase) {
            OpFault::None => Ok(()),
            OpFault::Kill => {
                obs::instant2(obs::SpanKind::Fault, "fault.kill", self.rank as u64);
                let epoch = self.shared.tuning.epoch;
                self.fatal(AkError::RankDead { rank: self.rank, epoch })
            }
            OpFault::Stall => {
                obs::instant2(obs::SpanKind::Fault, "fault.stall", self.rank as u64);
                // Park on the fabric (not a raw sleep): the watchdog's
                // `abort_all` must be able to release a stalled rank.
                let deadline = Instant::now() + self.recv_timeout();
                let mut st = self.shared.lock();
                loop {
                    if let Some(a) = st.abort {
                        drop(st);
                        return Err(self.rank_dead(a));
                    }
                    let now = Instant::now();
                    if now >= deadline {
                        let waited = self.recv_timeout();
                        drop(st);
                        return Err(self.timeout_err(
                            op,
                            None,
                            waited,
                            "injected stall never aborted".into(),
                            true,
                        ));
                    }
                    let (g, _) = self
                        .shared
                        .cv
                        .wait_timeout(st, deadline - now)
                        .unwrap_or_else(|e| e.into_inner());
                    st = g;
                }
            }
        }
    }

    /// The credit cap of the `self.rank → dst` link: minimum over the
    /// path's hops of the per-kind caps.
    fn link_cap(&self, hops: &[LinkKind]) -> usize {
        hops.iter().map(|&k| self.shared.tuning.cap(k)).min().unwrap_or(usize::MAX)
    }

    fn hops_to(&self, dst: usize) -> Vec<LinkKind> {
        let is_dev = self.is_device() && self.shared.device[dst];
        self.shared.spec.hops(self.rank, dst, self.shared.mode, is_dev)
    }

    /// Register a fabric wait with the hb detector (no-op unless
    /// [`CommTuning::hb_check`]); returns the named cycle when the
    /// registration closed one.
    fn hb_register(&self, st: &mut State, wait: Wait) -> Option<String> {
        let State { hb, phases, .. } = st;
        hb.as_mut().and_then(|hb| hb.register_wait(self.rank, wait, phases))
    }

    /// This rank stopped waiting (delivered, admitted, errored, or
    /// woken by an abort): drop its wait-for edge.
    fn hb_clear(&self, st: &mut State) {
        if let Some(hb) = st.hb.as_mut() {
            hb.clear_wait(self.rank);
        }
    }

    /// A registration closed a wait-for cycle: trip the coordinated
    /// abort (the peers in the cycle are parked and cannot make
    /// progress) and surface the typed deadlock diagnosis.
    fn hb_deadlock<T>(&mut self, mut st: MutexGuard<'_, State>, mut cycle: String) -> AkResult<T> {
        self.hb_clear(&mut st);
        if st.abort.is_none() {
            st.abort = Some(Abort { rank: self.rank, epoch: self.shared.tuning.epoch });
        }
        self.shared.cv.notify_all();
        drop(st);
        // Attach the live span stacks: what each traced thread was
        // inside when the cycle closed (empty when tracing is off).
        let stacks = obs::live_stacks_table();
        if !stacks.is_empty() {
            cycle.push('\n');
            cycle.push_str(&stacks);
        }
        self.fatal(AkError::Deadlock { rank: self.rank, cycle })
    }

    /// This rank's happens-before vector clock (one component per
    /// rank); `None` unless [`CommTuning::hb_check`] is on.
    pub fn hb_clock(&self) -> Option<Vec<u64>> {
        self.shared.lock().hb.as_ref().map(|hb| hb.clock(self.rank).0.clone())
    }

    /// Enqueue under the lock after admission (credit already charged
    /// on every kind in `hops`; the message returns it on consumption).
    fn enqueue(
        &self,
        st: &mut State,
        dst: usize,
        tag: u64,
        bytes: &[u8],
        arrive: f64,
        hops: Vec<LinkKind>,
    ) {
        let stamp = match st.hb.as_mut() {
            Some(hb) => {
                // The receiver (if parked on exactly this channel) is
                // about to wake: drop its wait edge so the pending
                // wake-up cannot close a stale cycle.
                hb.on_enqueue(dst, self.rank, tag);
                Some(hb.on_send(self.rank, dst, tag))
            }
            None => None,
        };
        st.inboxes[dst].push_back(Msg {
            src: self.rank,
            tag,
            bytes: bytes.to_vec(),
            arrive,
            charged: bytes.len(),
            stamp,
            hops,
        });
        self.shared.cv.notify_all();
    }

    fn self_send(&mut self, tag: u64, bytes: &[u8]) {
        let t = self.now();
        let rank = self.rank;
        let mut st = self.shared.lock();
        let stamp = st.hb.as_mut().map(|hb| hb.on_send(rank, rank, tag));
        st.inboxes[rank].push_back(Msg {
            src: rank,
            tag,
            bytes: bytes.to_vec(),
            arrive: t,
            charged: 0,
            stamp,
            hops: Vec::new(),
        });
        self.shared.cv.notify_all();
    }

    /// Evaluate link faults for one attempt; `Ok(extra_delay)` or the
    /// sender-side timeout a dropped message surfaces as (the simulated
    /// transport is acked — DESIGN.md §16).
    fn apply_link_faults(&mut self, dst: usize, dt: f64) -> AkResult<f64> {
        let Some(faults) = self.shared.tuning.faults.clone() else {
            return Ok(0.0);
        };
        match faults.on_send(self.rank, dst) {
            SendFault::Deliver => Ok(0.0),
            SendFault::Delayed(secs) => {
                self.shared.stats.delayed.fetch_add(1, Ordering::Relaxed);
                obs::instant2(obs::SpanKind::Fault, "fault.delay", dst as u64);
                Ok(secs)
            }
            SendFault::Dropped => {
                self.shared.stats.dropped.fetch_add(1, Ordering::Relaxed);
                obs::instant2(obs::SpanKind::Fault, "fault.drop", dst as u64);
                // The wire time was still spent before the loss.
                self.shared.clocks.advance(self.rank, dt);
                Err(self.timeout_err(
                    "send",
                    Some(dst),
                    Duration::ZERO,
                    "message dropped by injected link fault".into(),
                    false,
                ))
            }
        }
    }

    /// Point-to-point send. The sender's clock advances by the transfer
    /// time (its link is busy); the message carries its arrival time.
    /// Blocks while the link's in-flight bytes exceed its credit cap;
    /// self-sends are free (stay in device memory).
    pub fn send_bytes(&mut self, dst: usize, tag: u64, bytes: &[u8]) -> AkResult<()> {
        self.op_boundary("send")?;
        if dst == self.rank {
            self.self_send(tag, bytes);
            return Ok(());
        }
        let hops = self.hops_to(dst);
        let dt: f64 = hops.iter().map(|&k| self.shared.spec.hop_time(k, bytes.len())).sum();
        self.apply_link_faults(dst, dt)?;
        let cap = self.link_cap(&hops);
        let len = bytes.len();
        let link = self.rank * self.nranks + dst;
        let timeout = Duration::from_secs_f64(self.shared.tuning.send_timeout_secs.max(1e-3));
        let deadline = Instant::now() + timeout;
        let mut stalled = false;
        let mut st = self.shared.lock();
        loop {
            if let Some(a) = st.abort {
                self.hb_clear(&mut st);
                drop(st);
                return Err(self.rank_dead(a));
            }
            if !st.alive[dst] {
                self.hb_clear(&mut st);
                let epoch = self.shared.tuning.epoch;
                drop(st);
                return self.fatal(AkError::RankDead { rank: dst, epoch });
            }
            // Admission: fits under the cap, or the link is idle (a
            // single message larger than the cap must still progress).
            if st.in_flight[link] == 0 || st.in_flight[link] + len <= cap {
                self.hb_clear(&mut st);
                break;
            }
            if !stalled {
                stalled = true;
                self.shared.stats.credit_stalls.fetch_add(1, Ordering::Relaxed);
            }
            let now = Instant::now();
            if now >= deadline {
                self.hb_clear(&mut st);
                drop(st);
                return Err(self.timeout_err(
                    "send",
                    Some(dst),
                    timeout,
                    format!("link credit exhausted ({} bytes in flight, cap {cap})", len),
                    false,
                ));
            }
            let in_flight = st.in_flight[link];
            if let Some(cycle) =
                self.hb_register(&mut st, Wait::SendCredit { dst, tag, in_flight, cap })
            {
                return self.hb_deadlock(st, cycle);
            }
            let (g, _) = self
                .shared
                .cv
                .wait_timeout(st, deadline - now)
                .unwrap_or_else(|e| e.into_inner());
            st = g;
        }
        st.in_flight[link] += len;
        self.shared.stats.note_peak(st.in_flight[link]);
        track_kind_inflight(&mut st, &hops, true, len);
        if stalled {
            // Resume no earlier than the consumption that freed credit.
            self.shared.clocks.merge_at_least(self.rank, st.release_clock[link]);
        }
        let t_send = self.now();
        self.shared.stats.record(&hops, len);
        self.shared.clocks.advance(self.rank, dt);
        self.enqueue(&mut st, dst, tag, bytes, t_send + dt, hops);
        Ok(())
    }

    /// Non-blocking send: [`TrySend::Full`] when the link's credit is
    /// exhausted (the caller should make receive progress, then retry —
    /// the streamed exchange's interleaved loop). Faulted links error
    /// exactly like [`Self::send_bytes`].
    pub fn try_send_bytes(&mut self, dst: usize, tag: u64, bytes: &[u8]) -> AkResult<TrySend> {
        self.op_boundary("send")?;
        if dst == self.rank {
            self.self_send(tag, bytes);
            return Ok(TrySend::Sent);
        }
        let hops = self.hops_to(dst);
        let cap = self.link_cap(&hops);
        let len = bytes.len();
        let link = self.rank * self.nranks + dst;
        let mut st = self.shared.lock();
        if let Some(a) = st.abort {
            drop(st);
            return Err(self.rank_dead(a));
        }
        if !st.alive[dst] {
            let epoch = self.shared.tuning.epoch;
            drop(st);
            return self.fatal(AkError::RankDead { rank: dst, epoch });
        }
        if !(st.in_flight[link] == 0 || st.in_flight[link] + len <= cap) {
            return Ok(TrySend::Full);
        }
        drop(st);
        let dt: f64 = hops.iter().map(|&k| self.shared.spec.hop_time(k, bytes.len())).sum();
        self.apply_link_faults(dst, dt)?;
        let mut st = self.shared.lock();
        // Re-check admission: the fault evaluation dropped the lock.
        if !(st.in_flight[link] == 0 || st.in_flight[link] + len <= cap) {
            return Ok(TrySend::Full);
        }
        st.in_flight[link] += len;
        self.shared.stats.note_peak(st.in_flight[link]);
        track_kind_inflight(&mut st, &hops, true, len);
        let t_send = self.now();
        self.shared.stats.record(&hops, len);
        self.shared.clocks.advance(self.rank, dt);
        self.enqueue(&mut st, dst, tag, bytes, t_send + dt, hops);
        Ok(TrySend::Sent)
    }

    /// Merge this rank's clock with the last credit-release time of its
    /// link to `dst`. The interleaved exchange calls this when a
    /// previously-`Full` send finally goes through, so the stall is
    /// honest in simulated time too.
    pub fn sync_link_release(&self, dst: usize) {
        let link = self.rank * self.nranks + dst;
        let t = self.shared.lock().release_clock[link];
        self.shared.clocks.merge_at_least(self.rank, t);
    }

    /// Release a consumed message's credit and merge arrival time. With
    /// [`CommTuning::hb_check`] on, also joins the message's clock stamp
    /// into this rank and verifies per-`(src, dst, tag)` delivery
    /// monotonicity — a reordered delivery is a fabric protocol bug and
    /// fails the endpoint with [`AkError::Internal`].
    fn consume(&mut self, m: Msg) -> AkResult<Vec<u8>> {
        if m.charged > 0 || m.stamp.is_some() {
            let link = m.src * self.nranks + self.rank;
            let mut st = self.shared.lock();
            if m.charged > 0 {
                st.in_flight[link] = st.in_flight[link].saturating_sub(m.charged);
                track_kind_inflight(&mut st, &m.hops, false, m.charged);
                let t = self.shared.clocks.get(self.rank).max(m.arrive);
                if t > st.release_clock[link] {
                    st.release_clock[link] = t;
                }
                if let Some(hb) = st.hb.as_mut() {
                    // The sender (if parked on this link's credit) is
                    // about to wake: drop its wait edge so it cannot
                    // close a stale cycle while its wake-up is pending.
                    hb.on_credit_release(m.src, self.rank);
                }
                self.shared.cv.notify_all();
            }
            if let Some((stamp, seq)) = &m.stamp {
                if let Some(hb) = st.hb.as_mut() {
                    if let Err(detail) = hb.on_consume(self.rank, m.src, m.tag, stamp, *seq) {
                        drop(st);
                        return self.fatal(AkError::Internal(anyhow::anyhow!(detail)));
                    }
                }
            }
        }
        self.shared.clocks.merge_at_least(self.rank, m.arrive);
        Ok(m.bytes)
    }

    fn stash(&mut self, m: Msg) {
        self.stashed += m.bytes.len();
        self.pending.entry((m.src, m.tag)).or_default().push_back(m);
    }

    fn unstash(&mut self, key: (usize, u64)) -> Option<Msg> {
        let m = self.pending.get_mut(&key).and_then(VecDeque::pop_front)?;
        self.stashed -= m.bytes.len();
        Some(m)
    }

    /// Bytes currently parked in the out-of-order stash (still holding
    /// link credit; bounded by the sum of this rank's inbound caps).
    pub fn stashed_bytes(&self) -> usize {
        self.stashed
    }

    /// Blocking receive of the next message from `src` with `tag`.
    /// Merges the arrival time into this rank's clock. Fails with
    /// [`AkError::RankDead`] when `src` is dead with nothing left to
    /// deliver, or [`AkError::CommTimeout`] at the receive deadline.
    pub fn recv_bytes(&mut self, src: usize, tag: u64) -> AkResult<Vec<u8>> {
        self.op_boundary("recv")?;
        let key = (src, tag);
        if let Some(m) = self.unstash(key) {
            return self.consume(m);
        }
        let timeout = self.recv_timeout();
        let deadline = Instant::now() + timeout;
        let mut st = self.shared.lock();
        loop {
            // Drain my inbox in arrival order; stash mismatches (their
            // credit stays held until someone consumes them).
            let mut found = None;
            while let Some(m) = st.inboxes[self.rank].pop_front() {
                if (m.src, m.tag) == key {
                    found = Some(m);
                    break;
                }
                self.stashed += m.bytes.len();
                self.pending.entry((m.src, m.tag)).or_default().push_back(m);
            }
            if let Some(m) = found {
                self.hb_clear(&mut st);
                drop(st);
                return self.consume(m);
            }
            // Nothing deliverable: check for abort / dead peer, then wait.
            if let Some(a) = st.abort {
                self.hb_clear(&mut st);
                drop(st);
                return Err(self.rank_dead(a));
            }
            if !st.alive[src] {
                self.hb_clear(&mut st);
                let epoch = self.shared.tuning.epoch;
                drop(st);
                return self.fatal(AkError::RankDead { rank: src, epoch });
            }
            let now = Instant::now();
            if now >= deadline {
                self.hb_clear(&mut st);
                drop(st);
                return Err(self.timeout_err(
                    "recv",
                    Some(src),
                    timeout,
                    format!("no message with tag {tag:#x}"),
                    true,
                ));
            }
            if let Some(cycle) = self.hb_register(&mut st, Wait::Recv { src, tag }) {
                return self.hb_deadlock(st, cycle);
            }
            let (g, _) = self
                .shared
                .cv
                .wait_timeout(st, deadline - now)
                .unwrap_or_else(|e| e.into_inner());
            st = g;
        }
    }

    /// Non-blocking receive of the next message carrying `tag` from
    /// *any* source (stash first, then inbox arrival order). Returns
    /// `Ok(None)` when nothing with `tag` is available right now.
    pub fn try_recv_any(&mut self, tag: u64) -> AkResult<Option<(usize, Vec<u8>)>> {
        for src in 0..self.nranks {
            if let Some(m) = self.unstash((src, tag)) {
                let src = m.src;
                return Ok(Some((src, self.consume(m)?)));
            }
        }
        let mut st = self.shared.lock();
        let mut found = None;
        while let Some(m) = st.inboxes[self.rank].pop_front() {
            if m.tag == tag {
                found = Some(m);
                break;
            }
            self.stashed += m.bytes.len();
            self.pending.entry((m.src, m.tag)).or_default().push_back(m);
        }
        match found {
            Some(m) => {
                drop(st);
                let src = m.src;
                Ok(Some((src, self.consume(m)?)))
            }
            None => {
                if let Some(a) = st.abort {
                    drop(st);
                    return Err(self.rank_dead(a));
                }
                Ok(None)
            }
        }
    }

    /// Park until fabric activity that could unblock this rank (message
    /// arrival, credit release, abort) or `max_wait`, whichever first.
    pub fn wait_activity(&mut self, max_wait: Duration) -> AkResult<()> {
        let st = self.shared.lock();
        if let Some(a) = st.abort {
            drop(st);
            return Err(self.rank_dead(a));
        }
        if !st.inboxes[self.rank].is_empty() {
            return Ok(());
        }
        let (st, _) =
            self.shared.cv.wait_timeout(st, max_wait).unwrap_or_else(|e| e.into_inner());
        if let Some(a) = st.abort {
            drop(st);
            return Err(self.rank_dead(a));
        }
        Ok(())
    }

    /// [`Self::send_bytes`] with bounded exponential backoff on
    /// retryable timeouts (fault drops, credit starvation); fails fast
    /// on [`AkError::RankDead`]. Backoff advances the *simulated*
    /// clock with deterministic seeded jitter — see
    /// [`RetryPolicy::backoff_secs`].
    pub fn send_retry(&mut self, dst: usize, tag: u64, bytes: &[u8]) -> AkResult<()> {
        let policy = self.retry_policy();
        let mut attempt = 1u32;
        loop {
            match self.send_bytes(dst, tag, bytes) {
                Ok(()) => return Ok(()),
                Err(AkError::CommTimeout { .. }) if attempt < policy.max_attempts => {
                    let wait = policy.backoff_secs(self.rank, dst, tag, attempt);
                    self.shared.clocks.advance(self.rank, wait);
                    self.shared.stats.retries.fetch_add(1, Ordering::Relaxed);
                    obs::instant2(obs::SpanKind::Retry, "send.retry", u64::from(attempt));
                    attempt += 1;
                }
                Err(e) => return Err(e),
            }
        }
    }

    /// Typed point-to-point send of a key slice.
    pub fn send<K: SortKey>(&mut self, dst: usize, tag: u64, xs: &[K]) -> AkResult<()> {
        self.send_bytes(dst, tag, &vec_to_bytes(xs))
    }

    /// Typed point-to-point receive.
    pub fn recv<K: SortKey>(&mut self, src: usize, tag: u64) -> AkResult<Vec<K>> {
        Ok(bytes_to_vec(&self.recv_bytes(src, tag)?))
    }

    /// Synchronise all ranks (abortable generation barrier + clock
    /// max-merge). Fails with [`AkError::RankDead`] when a participant
    /// died instead of hanging forever.
    pub fn barrier(&mut self) -> AkResult<()> {
        self.coll_seq += 1;
        if self.nranks == 1 {
            return Ok(());
        }
        let _span = obs::span(obs::SpanKind::Collective, "barrier");
        let timeout = self.recv_timeout();
        let deadline = Instant::now() + timeout;
        let mut st = self.shared.lock();
        let gen = st.bar_gen;
        st.bar_arrived += 1;
        if let Some(hb) = st.hb.as_mut() {
            hb.barrier_arrive(self.rank, gen);
        }
        if st.bar_arrived == self.nranks {
            // Everyone else is parked inside the wait loop below (they
            // cannot leave until the generation advances, which happens
            // only here, under the lock) — the clocks are quiescent, as
            // `barrier_sync` requires.
            self.shared.clocks.barrier_sync();
            if let Some(hb) = st.hb.as_mut() {
                hb.barrier_complete();
            }
            st.bar_arrived = 0;
            st.bar_gen += 1;
            self.shared.cv.notify_all();
            return Ok(());
        }
        loop {
            if st.bar_gen != gen {
                self.hb_clear(&mut st);
                return Ok(());
            }
            if let Some(a) = st.abort {
                self.hb_clear(&mut st);
                drop(st);
                return Err(self.rank_dead(a));
            }
            // A dead participant will never arrive: fail fast with
            // attribution. (Clean completions can't trip this — every
            // rank passes the final barrier before any endpoint drops,
            // and the generation check above runs first.)
            if let Some(d) = st.alive.iter().position(|&a| !a) {
                self.hb_clear(&mut st);
                let epoch = self.shared.tuning.epoch;
                drop(st);
                return self.fatal(AkError::RankDead { rank: d, epoch });
            }
            let now = Instant::now();
            if now >= deadline {
                self.hb_clear(&mut st);
                drop(st);
                return Err(self.timeout_err(
                    "barrier",
                    None,
                    timeout,
                    format!("generation {gen} never completed"),
                    true,
                ));
            }
            if let Some(cycle) = self.hb_register(&mut st, Wait::Barrier { gen }) {
                return self.hb_deadlock(st, cycle);
            }
            let (g, _) = self
                .shared
                .cv
                .wait_timeout(st, deadline - now)
                .unwrap_or_else(|e| e.into_inner());
            st = g;
        }
    }

    pub(super) fn next_coll_tag(&mut self) -> u64 {
        self.coll_seq += 1;
        // Collective tags live in the top half of the tag space.
        (1 << 63) | self.coll_seq
    }

    /// Reserve one collective tag for a caller-driven collective built
    /// from raw sends/recvs (e.g. the streamed chunk-at-a-time exchange
    /// in `mpisort::exchange`). Every rank must call this at the same
    /// point in the collective schedule — the sequence number advances
    /// in lockstep exactly like the built-in collectives, so tags can
    /// never cross-talk between phases.
    pub fn collective_tag(&mut self) -> u64 {
        self.next_coll_tag()
    }

    /// Simulated times snapshot (rank -> seconds); for metrics.
    pub fn sim_time_of(&self, rank: usize) -> f64 {
        self.shared.clocks.get(rank)
    }

    /// Global simulated makespan.
    pub fn sim_makespan(&self) -> f64 {
        self.shared.clocks.global_max()
    }
}

impl Drop for Endpoint {
    fn drop(&mut self) {
        let died = self.failed || (!self.finished && std::thread::panicking());
        let mut st = self.shared.lock();
        st.alive[self.rank] = false;
        // A dead rank waits on nothing: drop its wait-for edge so it
        // cannot appear in a later cycle diagnosis.
        self.hb_clear(&mut st);
        // Release credit held by this rank's unconsumed stash and inbox
        // so surviving senders aren't starved by a dead receiver.
        let drain: Vec<(usize, usize, Vec<LinkKind>)> = self
            .pending
            .values()
            .flatten()
            .map(|m| (m.src, m.charged, m.hops.clone()))
            .chain(st.inboxes[self.rank].iter().map(|m| (m.src, m.charged, m.hops.clone())))
            .collect();
        for (src, charged, hops) in drain {
            let link = src * self.nranks + self.rank;
            st.in_flight[link] = st.in_flight[link].saturating_sub(charged);
            track_kind_inflight(&mut st, &hops, false, charged);
        }
        st.inboxes[self.rank].clear();
        if died && st.abort.is_none() {
            st.abort = Some(Abort { rank: self.rank, epoch: self.shared.tuning.epoch });
        }
        self.shared.cv.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(n: usize) -> Vec<Endpoint> {
        Fabric::new(ClusterSpec::baskerville(), TransferMode::GpuDirect, vec![true; n])
    }

    fn mk_tuned(n: usize, tuning: CommTuning) -> Vec<Endpoint> {
        Fabric::new_with(ClusterSpec::baskerville(), TransferMode::GpuDirect, vec![true; n], tuning)
    }

    #[test]
    fn p2p_roundtrip() {
        let mut eps = mk(2);
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        let h = std::thread::spawn(move || e1.recv::<i32>(0, 7).unwrap());
        e0.send::<i32>(1, 7, &[1, 2, 3]).unwrap();
        assert_eq!(h.join().unwrap(), vec![1, 2, 3]);
        e0.finish();
    }

    #[test]
    fn clock_advances_on_transfer() {
        let mut eps = mk(2);
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        let payload = vec![0u8; 30 << 20]; // 30 MB over NVLink ≈ 100 µs
        let h = std::thread::spawn(move || {
            let b = e1.recv_bytes(0, 1).unwrap();
            (b.len(), e1.now())
        });
        e0.send_bytes(1, 1, &payload).unwrap();
        assert!(e0.now() > 50e-6, "sender time {}", e0.now());
        let (len, t1) = h.join().unwrap();
        assert_eq!(len, 30 << 20);
        assert!(t1 >= e0.now() * 0.99, "receiver {} sender {}", t1, e0.now());
    }

    #[test]
    fn out_of_order_tags() {
        let mut eps = mk(2);
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        let h = std::thread::spawn(move || {
            // Ask for tag 2 first even though tag 1 arrives first.
            let b = e1.recv::<i32>(0, 2).unwrap();
            let a = e1.recv::<i32>(0, 1).unwrap();
            (a, b)
        });
        e0.send::<i32>(1, 1, &[10]).unwrap();
        e0.send::<i32>(1, 2, &[20]).unwrap();
        let (a, b) = h.join().unwrap();
        assert_eq!(a, vec![10]);
        assert_eq!(b, vec![20]);
    }

    #[test]
    fn self_send_is_free() {
        let mut eps = mk(1);
        let mut e0 = eps.pop().unwrap();
        e0.send::<i64>(0, 3, &[5, 6]).unwrap();
        let t_before = e0.now();
        assert_eq!(e0.recv::<i64>(0, 3).unwrap(), vec![5, 6]);
        assert_eq!(e0.now(), t_before);
        assert_eq!(e0.stats().snapshot().0, 0); // not counted as traffic
    }

    #[test]
    fn stats_count_hops() {
        let mut eps =
            Fabric::new(ClusterSpec::baskerville(), TransferMode::CpuStaged, vec![true; 2]);
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        let h = std::thread::spawn(move || e1.recv::<i32>(0, 1).unwrap());
        e0.send::<i32>(1, 1, &[1; 256]).unwrap();
        h.join().unwrap();
        let stats = e0.stats();
        assert_eq!(stats.messages.load(Ordering::Relaxed), 1);
        assert_eq!(stats.bytes.load(Ordering::Relaxed), 1024);
        // Staged intra-node: 2 PCIe hops + hostmem hop.
        assert_eq!(stats.pcie_bytes.load(Ordering::Relaxed), 2048);
        assert_eq!(stats.hostmem_bytes.load(Ordering::Relaxed), 1024);
        assert_eq!(stats.nvlink_bytes.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn barrier_merges_clocks() {
        let eps = mk(3);
        let handles: Vec<_> = eps
            .into_iter()
            .map(|mut e| {
                std::thread::spawn(move || {
                    e.advance(e.rank() as f64); // ranks at t=0,1,2
                    e.barrier().unwrap();
                    let t = e.now();
                    e.finish();
                    t
                })
            })
            .collect();
        for h in handles {
            assert_eq!(h.join().unwrap(), 2.0);
        }
    }

    #[test]
    fn dead_peer_surfaces_as_rank_dead_not_panic() {
        let tuning = CommTuning { recv_timeout_secs: 5.0, ..CommTuning::default() };
        let mut eps = mk_tuned(2, tuning);
        let e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        drop(e1); // peer gone (clean drop, nothing queued)
        match e0.recv_bytes(1, 9) {
            Err(AkError::RankDead { rank: 1, .. }) => {}
            other => panic!("expected RankDead{{rank:1}}, got {other:?}"),
        }
        // ...and sends to the dead peer fail the same way.
        let mut eps = mk_tuned(2, CommTuning { send_timeout_secs: 5.0, ..CommTuning::default() });
        let e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        drop(e1);
        match e0.send_bytes(1, 9, &[0u8; 8]) {
            Err(AkError::RankDead { rank: 1, .. }) => {}
            other => panic!("expected RankDead{{rank:1}}, got {other:?}"),
        }
    }

    #[test]
    fn queued_messages_survive_a_clean_peer_drop() {
        // A peer that sent, then dropped cleanly: its messages are
        // still deliverable (message-first, dead-check-second).
        let mut eps = mk(2);
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        e0.send::<i32>(1, 4, &[42]).unwrap();
        e0.finish();
        drop(e0);
        assert_eq!(e1.recv::<i32>(0, 4).unwrap(), vec![42]);
    }

    #[test]
    fn recv_deadline_times_out() {
        let tuning = CommTuning { recv_timeout_secs: 0.05, ..CommTuning::default() };
        let mut eps = mk_tuned(2, tuning);
        let _e1 = eps.pop().unwrap(); // alive but silent
        let mut e0 = eps.pop().unwrap();
        let t0 = Instant::now();
        match e0.recv_bytes(1, 1) {
            Err(AkError::CommTimeout { op: "recv", rank: 0, peer: Some(1), .. }) => {}
            other => panic!("expected CommTimeout, got {other:?}"),
        }
        assert!(t0.elapsed() >= Duration::from_millis(45));
        assert_eq!(e0.stats().timeouts.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn credit_cap_blocks_sender_and_releases_on_consume() {
        let tuning = CommTuning {
            cap_nvlink: 4096,
            cap_ib: 4096,
            cap_pcie: 4096,
            cap_hostmem: 4096,
            send_timeout_secs: 10.0,
            ..CommTuning::default()
        };
        let mut eps = mk_tuned(2, tuning);
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        let h = std::thread::spawn(move || {
            // Consume slowly: the sender must stall on credit.
            std::thread::sleep(Duration::from_millis(50));
            for i in 0..8 {
                let b = e1.recv_bytes(0, i).unwrap();
                assert_eq!(b.len(), 3000);
            }
            e1.stats().peak_link_bytes.load(Ordering::Relaxed)
        });
        for i in 0..8 {
            e0.send_bytes(1, i, &[7u8; 3000]).unwrap();
        }
        let peak = h.join().unwrap();
        assert!(peak <= 4096, "peak in-flight {peak} exceeded the 4096-byte cap");
        assert!(
            e0.stats().credit_stalls.load(Ordering::Relaxed) >= 1,
            "sender never stalled on credit"
        );
        e0.finish();
    }

    #[test]
    fn oversized_message_admitted_only_when_idle() {
        let tuning = CommTuning {
            cap_nvlink: 1024,
            cap_ib: 1024,
            cap_pcie: 1024,
            cap_hostmem: 1024,
            send_timeout_secs: 10.0,
            ..CommTuning::default()
        };
        let mut eps = mk_tuned(2, tuning);
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        // Fill the link, then try an oversized message: Full until the
        // small one is consumed, admitted once idle.
        e0.send_bytes(1, 1, &[0u8; 1000]).unwrap();
        assert_eq!(e0.try_send_bytes(1, 2, &vec![0u8; 8192]).unwrap(), TrySend::Full);
        e1.recv_bytes(0, 1).unwrap();
        assert_eq!(e0.try_send_bytes(1, 2, &vec![0u8; 8192]).unwrap(), TrySend::Sent);
        assert_eq!(e1.recv_bytes(0, 2).unwrap().len(), 8192);
        e0.finish();
        e1.finish();
    }

    #[test]
    fn stash_holds_credit_until_consumed() {
        let tuning = CommTuning {
            cap_nvlink: 4096,
            cap_ib: 4096,
            cap_pcie: 4096,
            cap_hostmem: 4096,
            send_timeout_secs: 0.1,
            recv_timeout_secs: 0.1,
            ..CommTuning::default()
        };
        let mut eps = mk_tuned(2, tuning);
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        let h = std::thread::spawn(move || {
            // Ask for a tag the flood never sends: everything received
            // is stashed — credit stays held, so the stash is bounded
            // and the wait ends in a timeout, not an OOM.
            let r = e1.recv_bytes(0, 999);
            (e1.stashed_bytes(), r)
        });
        // Tag-skewed flood: more bytes than the cap, wrong tags.
        let mut send_err = None;
        for i in 0..32 {
            if let Err(e) = e0.send_bytes(1, i, &[1u8; 512]) {
                send_err = Some(e);
                break;
            }
        }
        let (stashed, recv_res) = h.join().unwrap();
        assert!(stashed <= 4096, "stash grew to {stashed} bytes, past the 4096 cap");
        assert!(
            matches!(recv_res, Err(AkError::CommTimeout { .. })),
            "flooded receiver should time out, got {recv_res:?}"
        );
        assert!(
            matches!(send_err, Some(AkError::CommTimeout { .. })),
            "blocked sender should time out, got {send_err:?}"
        );
    }

    #[test]
    fn hb_check_names_credit_recv_deadlock_cycle() {
        // The seeded deadlock regression (DESIGN.md §17): rank 1 parks
        // in a receive for a tag the flood never sends, rank 0's
        // tag-skewed flood exhausts the link credit — a genuine
        // 0 --send-credit--> 1 --recv--> 0 cycle. With hb_check on, the
        // detector must name that exact cycle the moment it closes
        // (long deadlines prove it is not a watchdog timeout).
        let tuning = CommTuning {
            cap_nvlink: 4096,
            cap_ib: 4096,
            cap_pcie: 4096,
            cap_hostmem: 4096,
            send_timeout_secs: 30.0,
            recv_timeout_secs: 30.0,
            hb_check: true,
            ..CommTuning::default()
        };
        let mut eps = mk_tuned(2, tuning);
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        let t0 = Instant::now();
        let h = std::thread::spawn(move || {
            e1.note_phase("exchange");
            e1.recv_bytes(0, 999)
        });
        e0.note_phase("exchange");
        let mut send_err = None;
        for i in 0..32 {
            if let Err(e) = e0.send_bytes(1, i, &[1u8; 512]) {
                send_err = Some(e);
                break;
            }
        }
        let recv_err = h.join().unwrap().expect_err("flooded receiver cannot succeed");
        let send_err = send_err.expect("the flood must block on credit and fail");
        assert!(
            t0.elapsed() < Duration::from_secs(10),
            "cycle diagnosis took {:?} — that is a timeout, not detection",
            t0.elapsed()
        );
        let errs = [send_err, recv_err];
        assert!(
            !errs.iter().any(|e| matches!(e, AkError::CommTimeout { .. })),
            "deadlock must be diagnosed, not timed out: {errs:?}"
        );
        let cycles: Vec<&str> = errs
            .iter()
            .filter_map(|e| match e {
                AkError::Deadlock { cycle, .. } => Some(cycle.as_str()),
                _ => None,
            })
            .collect();
        assert_eq!(cycles.len(), 1, "exactly one rank diagnoses the cycle: {errs:?}");
        let cycle = cycles[0];
        assert!(cycle.contains("rank 0") && cycle.contains("rank 1"), "{cycle}");
        assert!(cycle.contains("send-credit(link 0->1"), "{cycle}");
        assert!(cycle.contains("recv(src 0, tag 0x3e7"), "{cycle}");
        assert!(cycle.contains("phase=exchange"), "{cycle}");
        assert!(
            errs.iter().any(|e| matches!(e, AkError::RankDead { .. })),
            "the peer must wake with RankDead from the coordinated abort: {errs:?}"
        );
    }

    #[test]
    fn hb_clocks_propagate_through_p2p() {
        let tuning = CommTuning { hb_check: true, ..CommTuning::default() };
        let mut eps = mk_tuned(2, tuning);
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        let h = std::thread::spawn(move || {
            let v = e1.recv::<i32>(0, 7).unwrap();
            let clock = e1.hb_clock().unwrap();
            e1.finish();
            (v, clock)
        });
        e0.send::<i32>(1, 7, &[1, 2, 3]).unwrap();
        let sender = e0.hb_clock().unwrap();
        let (v, receiver) = h.join().unwrap();
        assert_eq!(v, vec![1, 2, 3]);
        assert!(sender[0] >= 1, "send must tick the sender: {sender:?}");
        assert!(
            receiver[0] >= sender[0],
            "consume must join the sender's stamp: {receiver:?} vs {sender:?}"
        );
        e0.finish();
    }

    #[test]
    fn credit_return_interleaving_is_deterministic() {
        // Single-threaded deterministic schedule over try_send/recv:
        // fill the link, observe Full, consume exactly one message
        // (credit returns at that step, not later), observe admission.
        let tuning = CommTuning {
            cap_nvlink: 1024,
            cap_ib: 1024,
            cap_pcie: 1024,
            cap_hostmem: 1024,
            hb_check: true,
            ..CommTuning::default()
        };
        let mut eps = mk_tuned(2, tuning);
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        assert_eq!(e0.try_send_bytes(1, 1, &[0u8; 700]).unwrap(), TrySend::Sent);
        assert_eq!(e0.try_send_bytes(1, 2, &[0u8; 700]).unwrap(), TrySend::Full);
        assert_eq!(e1.recv_bytes(0, 1).unwrap().len(), 700);
        assert_eq!(e0.try_send_bytes(1, 2, &[0u8; 700]).unwrap(), TrySend::Sent);
        assert_eq!(e1.recv_bytes(0, 2).unwrap().len(), 700);
        // A measured section under hb_check must not trip the detector.
        let (x, _) = e0.measured(|| 41 + 1);
        assert_eq!(x, 42);
        e0.finish();
        e1.finish();
    }

    #[test]
    fn out_of_order_stash_release_keeps_channel_fifo() {
        // hb_check's per-channel monotonicity must hold when delivery
        // is forced through the out-of-order stash: tag 2 is asked for
        // first, so both tag-1 messages are stashed and later released
        // — in per-channel FIFO order, or consume() would error.
        let tuning = CommTuning { hb_check: true, ..CommTuning::default() };
        let mut eps = mk_tuned(2, tuning);
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        e0.send::<i32>(1, 1, &[10]).unwrap();
        e0.send::<i32>(1, 1, &[11]).unwrap();
        e0.send::<i32>(1, 2, &[20]).unwrap();
        assert_eq!(e1.recv::<i32>(0, 2).unwrap(), vec![20]);
        assert_eq!(e1.recv::<i32>(0, 1).unwrap(), vec![10]);
        assert_eq!(e1.recv::<i32>(0, 1).unwrap(), vec![11]);
        let clock = e1.hb_clock().unwrap();
        assert!(clock[0] >= 3, "all three stamps joined: {clock:?}");
        e0.finish();
        e1.finish();
    }

    #[test]
    fn kill_fault_fires_at_message_boundary() {
        use super::super::fault::FaultPlan;
        let faults = FaultPlan::parse("kill:0:2", 1).unwrap().state();
        let tuning = CommTuning { faults: Some(faults), epoch: 3, ..CommTuning::default() };
        let mut eps = mk_tuned(2, tuning);
        let _e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        e0.send_bytes(1, 1, &[0u8; 8]).unwrap(); // op 1: survives
        match e0.send_bytes(1, 2, &[0u8; 8]) {
            Err(AkError::RankDead { rank: 0, epoch: 3 }) => {}
            other => panic!("expected RankDead at op 2, got {other:?}"),
        }
    }

    #[test]
    fn dropped_link_fault_surfaces_as_retryable_timeout() {
        use super::super::fault::FaultPlan;
        let faults = FaultPlan::parse("drop:0:1:1", 1).unwrap().state();
        let tuning = CommTuning { faults: Some(faults), ..CommTuning::default() };
        let mut eps = mk_tuned(2, tuning);
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        // First attempt is eaten; send_retry recovers deterministically.
        e0.send_retry(1, 5, &[9u8; 16]).unwrap();
        assert_eq!(e1.recv_bytes(0, 5).unwrap(), vec![9u8; 16]);
        assert_eq!(e0.stats().dropped.load(Ordering::Relaxed), 1);
        assert_eq!(e0.stats().retries.load(Ordering::Relaxed), 1);
        e0.finish();
        e1.finish();
    }

    #[test]
    fn abort_all_releases_blocked_ranks() {
        let tuning = CommTuning { recv_timeout_secs: 30.0, ..CommTuning::default() };
        let mut eps = mk_tuned(2, tuning);
        let _e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        let ctl = e0.ctl();
        let h = std::thread::spawn(move || e0.recv_bytes(1, 1));
        std::thread::sleep(Duration::from_millis(30));
        ctl.abort_all(1);
        match h.join().unwrap() {
            Err(AkError::RankDead { rank: 1, .. }) => {}
            other => panic!("expected RankDead from abort, got {other:?}"),
        }
        let d = ctl.diag_table();
        assert!(d.contains("rank 0") && d.contains("rank 1"), "{d}");
    }

    #[test]
    fn failed_drop_trips_coordinated_abort() {
        let tuning = CommTuning { recv_timeout_secs: 30.0, ..CommTuning::default() };
        let mut eps = mk_tuned(3, tuning);
        let mut e2 = eps.pop().unwrap();
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        // Rank 2 blocks on a message that never comes; rank 1 dies with
        // failure; rank 2 must wake with RankDead{rank:1}.
        let h = std::thread::spawn(move || e2.recv_bytes(0, 1));
        std::thread::sleep(Duration::from_millis(20));
        let _ = e1.fatal::<()>(AkError::RankDead { rank: 1, epoch: 0 });
        drop(e1);
        match h.join().unwrap() {
            Err(AkError::RankDead { rank: 1, .. }) => {}
            other => panic!("expected abort-propagated RankDead, got {other:?}"),
        }
        e0.finish();
    }

    #[test]
    fn fault_counter_snapshot_matches_the_registry() {
        // The snapshot is the schema contract: exactly the registered
        // fabric counter names, in registration order, values intact.
        let c = FaultCounters { credit_stalls: 1, retries: 2, timeouts: 3, dropped: 4 };
        let s = c.snapshot_with_recoveries(5);
        assert_eq!(s.names(), obs::FABRIC_COUNTERS.to_vec());
        assert_eq!(s.get("credit_stalls"), 1);
        assert_eq!(s.get("retries"), 2);
        assert_eq!(s.get("timeouts"), 3);
        assert_eq!(s.get("dropped"), 4);
        assert_eq!(s.get("recoveries"), 5);
    }

    #[test]
    fn kind_inflight_totals_return_to_zero() {
        let mut eps = mk(2);
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        e0.send_bytes(1, 7, &[9u8; 1024]).unwrap();
        {
            let st = e1.shared.lock();
            assert!(st.kind_in_flight.iter().sum::<usize>() >= 1024);
        }
        assert_eq!(e1.recv_bytes(0, 7).unwrap().len(), 1024);
        let st = e0.shared.lock();
        assert_eq!(st.kind_in_flight, [0; 4], "consumption must return per-kind credit");
        drop(st);
        e0.finish();
        e1.finish();
    }
}
