//! Deterministic link/rank fault injection and bounded retry policy
//! for the simulated fabric (DESIGN.md §16).
//!
//! A [`FaultPlan`] is parsed from a compact spec string (`--faults` /
//! `[comm] faults`), then instantiated once per *job* as an
//! [`Arc<FaultState>`] that persists across driver restart attempts —
//! one-shot rules (kill, stall) fire exactly once per job, so a
//! restarted rank does not die again at the same message boundary.
//!
//! The transport is modelled as *acked*: a dropped or partitioned
//! message surfaces at the **sender** as a retryable
//! [`crate::session::AkError::CommTimeout`], which is what lets the
//! bounded-backoff retry layer ([`RetryPolicy`]) recover transient
//! faults without any receiver-side protocol.
//!
//! Determinism: flaky-link draws use one [`Prng`] per rule *per link*,
//! and only the link's source rank ever draws from it, so the sequence
//! of drop decisions is a pure function of (seed, link, send index)
//! regardless of thread interleaving. The partition heal clock is the
//! global send-attempt counter, which is interleaving-dependent by
//! nature; partitions therefore heal "after roughly OPS sends", which
//! is all the recovery tests rely on.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::util::Prng;

/// One parsed fault rule. Spec grammar (comma-separated rules):
///
/// | spec                    | meaning                                        |
/// |-------------------------|------------------------------------------------|
/// | `drop:SRC:DST:N`        | drop the next `N` messages on link SRC→DST     |
/// | `flaky:SRC:DST:P`       | drop each message on SRC→DST with probability P|
/// | `delay:SRC:DST:SECS`    | add SECS simulated latency to SRC→DST          |
/// | `partition:K:OPS`       | links crossing the {&lt;K, ≥K} cut drop until the |
/// |                         | global send-attempt counter passes OPS (heal)  |
/// | `kill:RANK:N[:PHASE]`   | RANK dies at its N-th fabric op (optionally    |
/// |                         | counted only inside phase note PHASE); one-shot|
/// | `stall:RANK:N[:PHASE]`  | RANK hangs at its N-th op until aborted;       |
/// |                         | one-shot (the watchdog's `abort_all` frees it) |
#[derive(Clone, Debug, PartialEq)]
pub enum FaultRule {
    /// Drop the next `n` messages on the link.
    Drop {
        /// Source rank of the faulted link.
        src: usize,
        /// Destination rank of the faulted link.
        dst: usize,
        /// How many messages to eat.
        n: u64,
    },
    /// Drop each message on the link with probability `p`.
    Flaky {
        /// Source rank of the faulted link.
        src: usize,
        /// Destination rank of the faulted link.
        dst: usize,
        /// Per-message drop probability in `[0, 1)`.
        p: f64,
    },
    /// Add fixed simulated delivery latency to the link.
    Delay {
        /// Source rank of the faulted link.
        src: usize,
        /// Destination rank of the faulted link.
        dst: usize,
        /// Extra latency in simulated seconds.
        secs: f64,
    },
    /// Messages crossing the `{< k, >= k}` cut drop until healed.
    Partition {
        /// The cut point: ranks `< k` vs ranks `>= k`.
        k: usize,
        /// Global send-attempt count after which the partition heals.
        heal_ops: u64,
    },
    /// The rank returns `RankDead` from its `at_op`-th fabric op.
    Kill {
        /// The rank to kill.
        rank: usize,
        /// Which op (1-based) within the matching scope triggers it.
        at_op: u64,
        /// When set, only ops issued under this phase note count.
        phase: Option<String>,
    },
    /// The rank parks on the fabric at its `at_op`-th op until aborted.
    Stall {
        /// The rank to stall.
        rank: usize,
        /// Which op (1-based) within the matching scope triggers it.
        at_op: u64,
        /// When set, only ops issued under this phase note count.
        phase: Option<String>,
    },
}

/// A parsed, seeded fault-injection plan (see [`FaultRule`] grammar).
#[derive(Clone, Debug)]
pub struct FaultPlan {
    /// The rules, applied in order (first matching rule wins per event).
    pub rules: Vec<FaultRule>,
    /// Seed for probabilistic rules (flaky links).
    pub seed: u64,
}

impl FaultPlan {
    /// Parse a comma-separated spec string (grammar on [`FaultRule`]).
    pub fn parse(spec: &str, seed: u64) -> anyhow::Result<FaultPlan> {
        let mut rules = Vec::new();
        for item in spec.split(',').map(str::trim).filter(|s| !s.is_empty()) {
            let parts: Vec<&str> = item.split(':').collect();
            let usage = || anyhow::anyhow!("bad fault rule '{item}' (see --help for the grammar)");
            let num = |s: &str| s.parse::<u64>().map_err(|_| usage());
            let idx = |s: &str| s.parse::<usize>().map_err(|_| usage());
            let flt = |s: &str| s.parse::<f64>().map_err(|_| usage());
            let rule = match (parts[0], parts.len()) {
                ("drop", 4) => {
                    FaultRule::Drop { src: idx(parts[1])?, dst: idx(parts[2])?, n: num(parts[3])? }
                }
                ("flaky", 4) => {
                    let p = flt(parts[3])?;
                    anyhow::ensure!((0.0..1.0).contains(&p), "flaky probability {p} not in [0,1)");
                    FaultRule::Flaky { src: idx(parts[1])?, dst: idx(parts[2])?, p }
                }
                ("delay", 4) => FaultRule::Delay {
                    src: idx(parts[1])?,
                    dst: idx(parts[2])?,
                    secs: flt(parts[3])?,
                },
                ("partition", 3) => {
                    FaultRule::Partition { k: idx(parts[1])?, heal_ops: num(parts[2])? }
                }
                ("kill", 3 | 4) => FaultRule::Kill {
                    rank: idx(parts[1])?,
                    at_op: num(parts[2])?,
                    phase: parts.get(3).map(|s| s.to_string()),
                },
                ("stall", 3 | 4) => FaultRule::Stall {
                    rank: idx(parts[1])?,
                    at_op: num(parts[2])?,
                    phase: parts.get(3).map(|s| s.to_string()),
                },
                _ => return Err(usage()),
            };
            rules.push(rule);
        }
        anyhow::ensure!(!rules.is_empty(), "empty fault spec");
        Ok(FaultPlan { rules, seed })
    }

    /// Instantiate the mutable per-job state. Create this **once** per
    /// job and share the `Arc` across driver restart attempts so
    /// one-shot rules stay fired.
    pub fn state(&self) -> Arc<FaultState> {
        Arc::new(FaultState {
            drops: self
                .rules
                .iter()
                .map(|r| match r {
                    FaultRule::Drop { n, .. } => AtomicU64::new(*n),
                    _ => AtomicU64::new(0),
                })
                .collect(),
            flaky: self
                .rules
                .iter()
                .enumerate()
                .map(|(i, r)| match r {
                    FaultRule::Flaky { src, dst, .. } => Mutex::new(Prng::new(
                        self.seed ^ (i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
                            ^ ((*src as u64) << 32 | *dst as u64),
                    )),
                    _ => Mutex::new(Prng::new(0)),
                })
                .collect(),
            scoped_ops: self.rules.iter().map(|_| AtomicU64::new(0)).collect(),
            fired: self.rules.iter().map(|_| AtomicBool::new(false)).collect(),
            send_ops: AtomicU64::new(0),
            plan: self.clone(),
        })
    }
}

/// What the fault layer decided about one send attempt.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum SendFault {
    /// Deliver normally.
    Deliver,
    /// The message is eaten; the sender sees a retryable timeout.
    Dropped,
    /// Deliver with this much extra simulated latency.
    Delayed(f64),
}

/// What the fault layer decided about one endpoint op boundary.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum OpFault {
    /// Proceed.
    None,
    /// The rank dies here (`AkError::RankDead`).
    Kill,
    /// The rank parks on the fabric until the coordinated abort.
    Stall,
}

/// Mutable per-job fault state (shared across restart attempts).
#[derive(Debug)]
pub struct FaultState {
    plan: FaultPlan,
    /// Remaining drop budget per `Drop` rule (index-aligned).
    drops: Vec<AtomicU64>,
    /// Per-`Flaky`-rule link Prng. Only the link's source rank draws,
    /// so the stream is consumed in that rank's program order.
    flaky: Vec<Mutex<Prng>>,
    /// Per-rule matched-op counters (kill/stall phase scoping).
    scoped_ops: Vec<AtomicU64>,
    /// One-shot flags (kill/stall fire once per job).
    fired: Vec<AtomicBool>,
    /// Global send-attempt counter (the partition heal clock).
    send_ops: AtomicU64,
}

impl FaultState {
    /// Evaluate link faults for one send attempt on `src → dst`.
    /// First matching rule wins.
    pub fn on_send(&self, src: usize, dst: usize) -> SendFault {
        let op = self.send_ops.fetch_add(1, Ordering::Relaxed) + 1;
        for (i, rule) in self.plan.rules.iter().enumerate() {
            match rule {
                FaultRule::Drop { src: s, dst: d, .. } if *s == src && *d == dst => {
                    let took = self.drops[i]
                        .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |n| n.checked_sub(1))
                        .is_ok();
                    if took {
                        return SendFault::Dropped;
                    }
                }
                FaultRule::Flaky { src: s, dst: d, p } if *s == src && *d == dst => {
                    let roll = self.flaky[i].lock().unwrap_or_else(|e| e.into_inner()).uniform_f64();
                    if roll < *p {
                        return SendFault::Dropped;
                    }
                }
                FaultRule::Delay { src: s, dst: d, secs } if *s == src && *d == dst => {
                    return SendFault::Delayed(*secs);
                }
                FaultRule::Partition { k, heal_ops } if op <= *heal_ops => {
                    if (src < *k) != (dst < *k) {
                        return SendFault::Dropped;
                    }
                }
                _ => {}
            }
        }
        SendFault::Deliver
    }

    /// Evaluate rank faults at one endpoint op boundary. `phase` is the
    /// rank's current phase note (empty when none was set).
    pub fn on_op(&self, rank: usize, phase: &str) -> OpFault {
        for (i, rule) in self.plan.rules.iter().enumerate() {
            let (r, at_op, want_phase, fault) = match rule {
                FaultRule::Kill { rank, at_op, phase } => (*rank, *at_op, phase, OpFault::Kill),
                FaultRule::Stall { rank, at_op, phase } => (*rank, *at_op, phase, OpFault::Stall),
                _ => continue,
            };
            if r != rank || self.fired[i].load(Ordering::Relaxed) {
                continue;
            }
            if let Some(want) = want_phase {
                if want != phase {
                    continue;
                }
            }
            let seen = self.scoped_ops[i].fetch_add(1, Ordering::Relaxed) + 1;
            if seen >= at_op && !self.fired[i].swap(true, Ordering::Relaxed) {
                return fault;
            }
        }
        OpFault::None
    }
}

/// Bounded exponential backoff with deterministic seeded jitter for
/// sender-side retries of [`crate::session::AkError::CommTimeout`].
///
/// Backoff advances the *simulated* clock (a real rank would sit inside
/// `MPI_Send`); no wall time is slept, so fault tests stay fast.
#[derive(Clone, Debug, PartialEq)]
pub struct RetryPolicy {
    /// Total attempts including the first (1 disables retries).
    pub max_attempts: u32,
    /// Nominal backoff before the first retry, in simulated seconds.
    pub base_secs: f64,
    /// Multiplier per further retry.
    pub factor: f64,
    /// Per-step nominal cap, in simulated seconds.
    pub max_secs: f64,
    /// Jitter seed (derive from the run seed for reproducible runs).
    pub seed: u64,
}

impl Default for RetryPolicy {
    fn default() -> RetryPolicy {
        RetryPolicy { max_attempts: 4, base_secs: 1e-4, factor: 2.0, max_secs: 0.1, seed: 0 }
    }
}

impl RetryPolicy {
    /// Deterministic backoff before retry `attempt` (1-based): the
    /// nominal exponential step scaled by a seeded jitter in
    /// `[0.5, 1.0]`. Pure in `(self, rank, peer, tag, attempt)` — two
    /// calls with the same inputs return the same wait.
    pub fn backoff_secs(&self, rank: usize, peer: usize, tag: u64, attempt: u32) -> f64 {
        let nominal =
            (self.base_secs * self.factor.powi(attempt.saturating_sub(1) as i32)).min(self.max_secs);
        let mix = self
            .seed
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            .wrapping_add((rank as u64) << 40)
            .wrapping_add((peer as u64) << 20)
            .wrapping_add(tag)
            .wrapping_add((attempt as u64) << 56);
        let mut prng = Prng::new(mix);
        nominal * (0.5 + 0.5 * prng.uniform_f64())
    }

    /// The full backoff schedule for one `(rank, peer, tag)` message —
    /// one entry per possible retry (diagnostics and tests).
    pub fn schedule(&self, rank: usize, peer: usize, tag: u64) -> Vec<f64> {
        (1..self.max_attempts).map(|a| self.backoff_secs(rank, peer, tag, a)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_every_rule_kind() {
        let p = FaultPlan::parse(
            "drop:0:1:3, flaky:1:2:0.25, delay:2:0:0.005, partition:2:100, kill:1:7:exchange, stall:3:2",
            42,
        )
        .unwrap();
        assert_eq!(p.rules.len(), 6);
        assert_eq!(p.rules[0], FaultRule::Drop { src: 0, dst: 1, n: 3 });
        assert_eq!(
            p.rules[4],
            FaultRule::Kill { rank: 1, at_op: 7, phase: Some("exchange".into()) }
        );
        assert_eq!(p.rules[5], FaultRule::Stall { rank: 3, at_op: 2, phase: None });
        assert!(FaultPlan::parse("drop:0:1", 0).is_err());
        assert!(FaultPlan::parse("flaky:0:1:1.5", 0).is_err());
        assert!(FaultPlan::parse("", 0).is_err());
    }

    #[test]
    fn drop_rule_eats_exactly_n() {
        let st = FaultPlan::parse("drop:0:1:2", 0).unwrap().state();
        assert_eq!(st.on_send(0, 1), SendFault::Dropped);
        assert_eq!(st.on_send(0, 1), SendFault::Dropped);
        assert_eq!(st.on_send(0, 1), SendFault::Deliver);
        // Other links never match.
        assert_eq!(st.on_send(1, 0), SendFault::Deliver);
    }

    #[test]
    fn partition_heals_after_ops() {
        let st = FaultPlan::parse("partition:2:3", 0).unwrap().state();
        // Cross-cut sends drop while the heal clock is below 3...
        assert_eq!(st.on_send(0, 2), SendFault::Dropped);
        // ...same-side traffic is unaffected (but advances the clock)...
        assert_eq!(st.on_send(0, 1), SendFault::Deliver);
        assert_eq!(st.on_send(2, 3), SendFault::Deliver);
        // ...and the 4th attempt onward is healed.
        assert_eq!(st.on_send(0, 2), SendFault::Deliver);
    }

    #[test]
    fn kill_is_one_shot_and_phase_scoped() {
        let st = FaultPlan::parse("kill:1:2:exchange", 0).unwrap().state();
        // Ops outside the phase, or on other ranks, never count.
        assert_eq!(st.on_op(1, "splitters"), OpFault::None);
        assert_eq!(st.on_op(0, "exchange"), OpFault::None);
        assert_eq!(st.on_op(1, "exchange"), OpFault::None);
        assert_eq!(st.on_op(1, "exchange"), OpFault::Kill);
        // One-shot: a restarted rank sails through the same boundary.
        assert_eq!(st.on_op(1, "exchange"), OpFault::None);
    }

    #[test]
    fn flaky_draws_are_deterministic_per_seed() {
        let a = FaultPlan::parse("flaky:0:1:0.5", 7).unwrap().state();
        let b = FaultPlan::parse("flaky:0:1:0.5", 7).unwrap().state();
        let seq_a: Vec<_> = (0..64).map(|_| a.on_send(0, 1)).collect();
        let seq_b: Vec<_> = (0..64).map(|_| b.on_send(0, 1)).collect();
        assert_eq!(seq_a, seq_b);
        assert!(seq_a.contains(&SendFault::Dropped) && seq_a.contains(&SendFault::Deliver));
    }

    #[test]
    fn backoff_is_deterministic_bounded_and_jittered() {
        let p = RetryPolicy { max_attempts: 6, seed: 99, ..RetryPolicy::default() };
        let s1 = p.schedule(2, 5, 17);
        let s2 = p.schedule(2, 5, 17);
        assert_eq!(s1, s2);
        assert_eq!(s1.len(), 5);
        for (i, w) in s1.iter().enumerate() {
            let nominal = (p.base_secs * p.factor.powi(i as i32)).min(p.max_secs);
            assert!(*w >= 0.5 * nominal && *w <= nominal, "step {i}: {w} vs nominal {nominal}");
        }
        // Different links jitter differently.
        assert_ne!(p.schedule(2, 5, 17), p.schedule(3, 5, 17));
    }
}
