//! Happens-before tracking and deadlock detection for the fabric
//! (DESIGN.md §17), behind [`CommTuning::hb_check`].
//!
//! Three instruments, all living under the fabric's state mutex:
//!
//! - **Vector clocks** — every rank carries a [`VClock`]; sends tick
//!   and stamp the outgoing message, consumes join the stamp into the
//!   receiver, barriers join every clock. The clocks give each message
//!   a happens-before position that diagnostics (and tests) can read
//!   via `Endpoint::hb_clock`.
//! - **Per-channel monotonicity** — each `(src, dst, tag)` channel
//!   numbers its sends; [`HbState::on_consume`] rejects a delivery
//!   whose sequence number is not exactly the last-consumed + 1. The
//!   fabric's FIFO inboxes make this invariant structural today; the
//!   checker catches a future reordering bug at the boundary instead
//!   of as downstream corruption.
//! - **Wait-for graph** — a rank parked in the fabric registers what
//!   it waits on ([`Wait`]): the source of a blocking receive, the
//!   consumer whose link credit a blocked send needs, the unarrived
//!   ranks of a barrier generation, or the holder of the compute
//!   token. Each registration runs a cycle check; a closed cycle among
//!   *parked* ranks is a true deadlock (every edge's target is the
//!   only agent that can unblock the waiter), so detection is
//!   deterministic and immediate — a named cycle with per-rank
//!   diagnostics, not a watchdog timeout. The fabric turns it into
//!   [`AkError::Deadlock`] and trips the coordinated abort.
//!
//! The state mutex itself is deliberately *not* a graph node: it is
//! the detector's own monitor, held only for O(1) sections and never
//! across a park, so it cannot participate in a deadlock. Compute-token
//! edges can never close a cycle either (a `measured` section must not
//! communicate, so a holder is never parked in the fabric); they are
//! tracked so a cycle check sees through ranks queued on the token.
//!
//! [`CommTuning::hb_check`]: super::CommTuning::hb_check
//! [`AkError::Deadlock`]: crate::session::AkError::Deadlock

use std::collections::HashMap;

/// A vector clock: one logical-time component per rank.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct VClock(pub Vec<u64>);

impl VClock {
    /// The zero clock for `n` ranks.
    pub fn new(n: usize) -> VClock {
        VClock(vec![0; n])
    }

    /// Advance `rank`'s own component (a local event).
    pub fn tick(&mut self, rank: usize) {
        self.0[rank] += 1;
    }

    /// Component-wise maximum (receive/barrier join).
    pub fn join(&mut self, other: &VClock) {
        for (a, b) in self.0.iter_mut().zip(other.0.iter()) {
            *a = (*a).max(*b);
        }
    }

    /// True when every component of `self` is `<=` the matching
    /// component of `other` (self happened-before-or-equal other).
    pub fn le(&self, other: &VClock) -> bool {
        self.0.iter().zip(other.0.iter()).all(|(a, b)| a <= b)
    }
}

/// What a parked rank is waiting for — one out-edge set of the
/// wait-for graph.
#[derive(Clone, Copy, Debug)]
pub enum Wait {
    /// Blocked in a receive for a message from `src` with `tag`.
    Recv {
        /// The only rank that can send the awaited message.
        src: usize,
        /// The awaited tag.
        tag: u64,
    },
    /// Blocked in a send on exhausted link credit: only `dst`
    /// consuming frees the link.
    SendCredit {
        /// The receiver whose consumption returns the credit.
        dst: usize,
        /// Tag of the blocked message.
        tag: u64,
        /// In-flight bytes held against the link when the wait began.
        in_flight: usize,
        /// The link's credit cap.
        cap: usize,
    },
    /// Parked in barrier generation `gen`, waiting for every rank that
    /// has not arrived yet.
    Barrier {
        /// The barrier generation the rank is parked in.
        gen: u64,
    },
    /// Queued on the compute token (held by another rank).
    Compute,
}

/// The per-fabric happens-before state (guarded by the fabric's state
/// mutex; every method is O(ranks) or better).
#[derive(Debug)]
pub struct HbState {
    n: usize,
    clocks: Vec<VClock>,
    /// Next send sequence number per `(src, dst, tag)` channel.
    send_seq: HashMap<(usize, usize, u64), u64>,
    /// Last consumed sequence number per `(src, dst, tag)` channel.
    recv_seq: HashMap<(usize, usize, u64), u64>,
    waits: Vec<Option<Wait>>,
    bar_gen: u64,
    bar_arrived: Vec<bool>,
    compute_holder: Option<usize>,
}

impl HbState {
    /// Fresh state for `n` ranks.
    pub fn new(n: usize) -> HbState {
        HbState {
            n,
            clocks: vec![VClock::new(n); n],
            send_seq: HashMap::new(),
            recv_seq: HashMap::new(),
            waits: vec![None; n],
            bar_gen: 0,
            bar_arrived: vec![false; n],
            compute_holder: None,
        }
    }

    /// A send event on `(src, dst, tag)`: ticks the sender's clock and
    /// returns the stamp (clock snapshot, channel sequence number) the
    /// message carries.
    pub fn on_send(&mut self, src: usize, dst: usize, tag: u64) -> (VClock, u64) {
        self.clocks[src].tick(src);
        let seq = self.send_seq.entry((src, dst, tag)).or_insert(0);
        *seq += 1;
        (self.clocks[src].clone(), *seq)
    }

    /// A consume event at `dst`: verifies the channel's sequence is
    /// exactly last + 1 (FIFO delivery per `(src, dst, tag)`), then
    /// joins the message stamp into the receiver's clock. An
    /// out-of-order delivery returns the protocol-violation diagnostic.
    pub fn on_consume(
        &mut self,
        dst: usize,
        src: usize,
        tag: u64,
        stamp: &VClock,
        seq: u64,
    ) -> Result<(), String> {
        let last = self.recv_seq.entry((src, dst, tag)).or_insert(0);
        if seq != *last + 1 {
            return Err(format!(
                "hb_check: out-of-order delivery on channel {src}->{dst} tag {tag:#x}: \
                 consumed seq {seq} after seq {last}"
            ));
        }
        *last = seq;
        self.clocks[dst].join(stamp);
        self.clocks[dst].tick(dst);
        Ok(())
    }

    /// This rank's current vector clock.
    pub fn clock(&self, rank: usize) -> &VClock {
        &self.clocks[rank]
    }

    /// A rank arrived at barrier generation `gen`.
    pub fn barrier_arrive(&mut self, rank: usize, gen: u64) {
        if gen != self.bar_gen {
            self.bar_gen = gen;
            self.bar_arrived.iter_mut().for_each(|a| *a = false);
        }
        self.bar_arrived[rank] = true;
        self.clocks[rank].tick(rank);
    }

    /// The barrier generation completed: every clock joins every other
    /// (the barrier is a global synchronisation point).
    pub fn barrier_complete(&mut self) {
        let mut max = VClock::new(self.n);
        for c in &self.clocks {
            max.join(c);
        }
        for c in &mut self.clocks {
            *c = max.clone();
        }
    }

    /// Record (or clear, with `None`) the compute-token holder.
    pub fn set_compute_holder(&mut self, rank: Option<usize>) {
        self.compute_holder = rank;
    }

    /// The current compute-token holder, if any.
    pub fn compute_holder(&self) -> Option<usize> {
        self.compute_holder
    }

    /// Register that `rank` is about to park on `wait`, then check
    /// whether the registration closed a wait-for cycle. Returns the
    /// canonical cycle diagnostic if it did — a closed cycle among
    /// parked ranks is a true deadlock, diagnosed the moment it forms.
    /// `phases` are the per-rank phase notes for the diagnostic.
    pub fn register_wait(
        &mut self,
        rank: usize,
        wait: Wait,
        phases: &[&'static str],
    ) -> Option<String> {
        self.waits[rank] = Some(wait);
        self.find_cycle(rank, phases)
    }

    /// `rank` stopped waiting (delivered, admitted, errored, or woken
    /// by an abort).
    pub fn clear_wait(&mut self, rank: usize) {
        self.waits[rank] = None;
    }

    /// A message on `(src, dst, tag)` was just enqueued: if `dst` is
    /// parked in a receive for exactly that channel, its wake-up is
    /// already pending — drop its wait edge so a later registration
    /// cannot close a stale cycle through a rank that is about to run.
    pub fn on_enqueue(&mut self, dst: usize, src: usize, tag: u64) {
        if let Some(Wait::Recv { src: ws, tag: wt }) = self.waits[dst] {
            if ws == src && wt == tag {
                self.waits[dst] = None;
            }
        }
    }

    /// Credit returned on the `src -> dst` link (the receiver consumed
    /// a charged message): if `src` is parked on that link's credit,
    /// its wake-up is already pending — drop its wait edge.
    pub fn on_credit_release(&mut self, src: usize, dst: usize) {
        if let Some(Wait::SendCredit { dst: wd, .. }) = self.waits[src] {
            if wd == dst {
                self.waits[src] = None;
            }
        }
    }

    /// Ranks `r` currently waits on (the only agents able to unblock
    /// it). Stale barrier waits — a generation that already advanced —
    /// have no targets: the waiter is about to wake.
    fn targets(&self, r: usize) -> Vec<usize> {
        match self.waits[r] {
            None => Vec::new(),
            Some(Wait::Recv { src, .. }) => vec![src],
            Some(Wait::SendCredit { dst, .. }) => vec![dst],
            Some(Wait::Barrier { gen }) if gen == self.bar_gen => {
                (0..self.n).filter(|&x| !self.bar_arrived[x] && x != r).collect()
            }
            Some(Wait::Barrier { .. }) => Vec::new(),
            Some(Wait::Compute) => {
                self.compute_holder.into_iter().filter(|&h| h != r).collect()
            }
        }
    }

    /// Depth-first search for a path `start -> ... -> start`. Any
    /// newly-closed cycle must pass through the rank that just
    /// registered (edges of other ranks only ever shrink), so searching
    /// from `start` alone is complete.
    fn find_cycle(&self, start: usize, phases: &[&'static str]) -> Option<String> {
        let mut path = vec![start];
        let mut visited = vec![false; self.n];
        visited[start] = true;
        if self.dfs(start, start, &mut path, &mut visited) {
            Some(self.format_cycle(&path, phases))
        } else {
            None
        }
    }

    fn dfs(
        &self,
        node: usize,
        start: usize,
        path: &mut Vec<usize>,
        visited: &mut [bool],
    ) -> bool {
        for t in self.targets(node) {
            if t == start {
                return true;
            }
            if !visited[t] {
                visited[t] = true;
                path.push(t);
                if self.dfs(t, start, path, visited) {
                    return true;
                }
                path.pop();
            }
        }
        false
    }

    fn edge_label(&self, r: usize) -> String {
        match self.waits[r] {
            Some(Wait::Recv { src, tag }) => format!("--recv(src {src}, tag {tag:#x})--"),
            Some(Wait::SendCredit { dst, tag, in_flight, cap }) => format!(
                "--send-credit(link {r}->{dst}, in-flight {in_flight}/{cap} bytes, \
                 tag {tag:#x})--"
            ),
            Some(Wait::Barrier { gen }) => format!("--barrier(gen {gen})--"),
            Some(Wait::Compute) => "--compute-token--".to_string(),
            None => "--?--".to_string(),
        }
    }

    /// Canonical, deterministic rendering: the cycle is rotated to
    /// start at its smallest rank, each hop names the wait kind with
    /// its link/credit/tag details and the waiter's phase note.
    fn format_cycle(&self, path: &[usize], phases: &[&'static str]) -> String {
        let pivot = path
            .iter()
            .enumerate()
            .min_by_key(|(_, &r)| r)
            .map(|(i, _)| i)
            .unwrap_or(0);
        let rot: Vec<usize> =
            (0..path.len()).map(|i| path[(pivot + i) % path.len()]).collect();
        let mut s = String::from("wait-for cycle: ");
        for (i, &r) in rot.iter().enumerate() {
            let next = rot[(i + 1) % rot.len()];
            let phase = phases.get(r).copied().unwrap_or("?");
            s.push_str(&format!("rank {r} [phase={phase}] {}> rank {next}", self.edge_label(r)));
            if i + 1 < rot.len() {
                s.push_str("; ");
            }
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clocks_propagate_happens_before() {
        let mut hb = HbState::new(3);
        let (stamp, seq) = hb.on_send(0, 1, 7);
        assert_eq!(seq, 1);
        assert_eq!(stamp.0, vec![1, 0, 0]);
        hb.on_consume(1, 0, 7, &stamp, seq).unwrap();
        // The receiver's clock now dominates the sender's stamp.
        assert!(stamp.le(hb.clock(1)));
        assert_eq!(hb.clock(1).0, vec![1, 1, 0]);
        // Relay 1 -> 2 carries rank 0's component transitively.
        let (stamp2, seq2) = hb.on_send(1, 2, 9);
        hb.on_consume(2, 1, 9, &stamp2, seq2).unwrap();
        assert_eq!(hb.clock(2).0[0], 1, "transitive happens-before lost");
    }

    #[test]
    fn out_of_order_consume_is_a_protocol_violation() {
        let mut hb = HbState::new(2);
        let (s1, q1) = hb.on_send(0, 1, 5);
        let (s2, q2) = hb.on_send(0, 1, 5);
        // Consuming the second message first is the reordering bug the
        // checker exists to catch.
        let err = hb.on_consume(1, 0, 5, &s2, q2).unwrap_err();
        assert!(err.contains("out-of-order"), "{err}");
        assert!(err.contains("0->1"), "{err}");
        hb.on_consume(1, 0, 5, &s1, q1).unwrap();
        hb.on_consume(1, 0, 5, &s2, q2).unwrap();
        // Distinct tags are distinct channels: no false positive.
        let (s3, q3) = hb.on_send(0, 1, 6);
        hb.on_consume(1, 0, 6, &s3, q3).unwrap();
    }

    #[test]
    fn barrier_joins_every_clock() {
        let mut hb = HbState::new(2);
        let (s, q) = hb.on_send(0, 0, 1);
        hb.on_consume(0, 0, 1, &s, q).unwrap();
        hb.barrier_arrive(0, 0);
        hb.barrier_arrive(1, 0);
        hb.barrier_complete();
        assert_eq!(hb.clock(0), hb.clock(1));
        assert!(hb.clock(1).0[0] >= 2, "rank 0's history not joined: {:?}", hb.clock(1));
    }

    #[test]
    fn two_rank_credit_recv_cycle_is_named() {
        let phases = ["exchange", "exchange"];
        let mut hb = HbState::new(2);
        assert!(
            hb.register_wait(1, Wait::Recv { src: 0, tag: 999 }, &phases).is_none(),
            "a single wait is not a cycle"
        );
        let cycle = hb
            .register_wait(
                0,
                Wait::SendCredit { dst: 1, tag: 8, in_flight: 4096, cap: 4096 },
                &phases,
            )
            .expect("the second wait closes the cycle");
        assert!(cycle.contains("rank 0") && cycle.contains("rank 1"), "{cycle}");
        assert!(cycle.contains("send-credit(link 0->1"), "{cycle}");
        assert!(cycle.contains("recv(src 0, tag 0x3e7"), "{cycle}");
        assert!(cycle.contains("phase=exchange"), "{cycle}");
        // Clearing either wait reopens the graph.
        hb.clear_wait(0);
        assert!(hb
            .register_wait(
                0,
                Wait::SendCredit { dst: 1, tag: 8, in_flight: 4096, cap: 4096 },
                &phases,
            )
            .is_some());
        hb.clear_wait(1);
        assert!(hb
            .register_wait(
                0,
                Wait::SendCredit { dst: 1, tag: 8, in_flight: 4096, cap: 4096 },
                &phases,
            )
            .is_none());
    }

    #[test]
    fn pending_wakeups_suppress_stale_cycles() {
        let phases = ["exchange", "exchange"];
        // Receiver side: rank 1 parks on recv(0, 7); the awaited
        // message is enqueued (wake-up pending) before rank 0 blocks
        // on that link's credit — no cycle, rank 1 is about to run.
        let mut hb = HbState::new(2);
        assert!(hb.register_wait(1, Wait::Recv { src: 0, tag: 7 }, &phases).is_none());
        hb.on_enqueue(1, 0, 7);
        assert!(hb
            .register_wait(
                0,
                Wait::SendCredit { dst: 1, tag: 7, in_flight: 64, cap: 64 },
                &phases,
            )
            .is_none());
        // A different channel must NOT clear the wait.
        let mut hb = HbState::new(2);
        assert!(hb.register_wait(1, Wait::Recv { src: 0, tag: 7 }, &phases).is_none());
        hb.on_enqueue(1, 0, 8);
        assert!(hb
            .register_wait(
                0,
                Wait::SendCredit { dst: 1, tag: 7, in_flight: 64, cap: 64 },
                &phases,
            )
            .is_some());
        // Sender side: rank 0 parks on credit to 1; rank 1 consumes
        // (credit released, wake-up pending) before parking in a recv
        // on rank 0 — no cycle.
        let mut hb = HbState::new(2);
        assert!(hb
            .register_wait(
                0,
                Wait::SendCredit { dst: 1, tag: 7, in_flight: 64, cap: 64 },
                &phases,
            )
            .is_none());
        hb.on_credit_release(0, 1);
        assert!(hb.register_wait(1, Wait::Recv { src: 0, tag: 9 }, &phases).is_none());
    }

    #[test]
    fn three_rank_cycle_through_barrier() {
        // Rank 0 parks in a barrier (rank 1 and 2 unarrived); rank 1
        // recv-waits on 2; rank 2 credit-waits on 1's consumption. The
        // 1 -> 2 -> 1 cycle excludes rank 0 — the detector must name
        // exactly the deadlocked pair, canonically from rank 1.
        let phases = ["final", "exchange", "exchange"];
        let mut hb = HbState::new(3);
        hb.barrier_arrive(0, 0);
        assert!(hb.register_wait(0, Wait::Barrier { gen: 0 }, &phases).is_none());
        assert!(hb.register_wait(1, Wait::Recv { src: 2, tag: 3 }, &phases).is_none());
        let cycle = hb
            .register_wait(
                2,
                Wait::SendCredit { dst: 1, tag: 4, in_flight: 100, cap: 64 },
                &phases,
            )
            .expect("1 <-> 2 cycle");
        assert!(cycle.starts_with("wait-for cycle: rank 1"), "{cycle}");
        assert!(!cycle.contains("rank 0"), "rank 0 is not in the cycle: {cycle}");
    }

    #[test]
    fn stale_barrier_generation_has_no_edges() {
        let phases = ["start", "start"];
        let mut hb = HbState::new(2);
        // Rank 0 still holds a wait from generation 0; generation has
        // moved to 1 — its edges are gone, so no cycle can close
        // through a waiter that is about to wake.
        assert!(hb.register_wait(0, Wait::Barrier { gen: 0 }, &phases).is_none());
        hb.barrier_arrive(1, 1);
        assert!(hb.register_wait(1, Wait::Recv { src: 0, tag: 1 }, &phases).is_none());
    }

    #[test]
    fn compute_token_edges_see_through_queued_ranks() {
        // Rank 1 queues on the compute token held by rank 2; rank 2 is
        // not parked, so no cycle — but once rank 2 recv-waits on a
        // rank that transitively waits on rank 1, the path through the
        // token closes the loop.
        let phases = ["local-sort", "local-sort", "local-sort"];
        let mut hb = HbState::new(3);
        hb.set_compute_holder(Some(2));
        assert!(hb.register_wait(1, Wait::Compute, &phases).is_none());
        let cycle = hb
            .register_wait(2, Wait::Recv { src: 1, tag: 11 }, &phases)
            .expect("token edge must participate in the cycle");
        assert!(cycle.contains("compute-token"), "{cycle}");
    }
}
