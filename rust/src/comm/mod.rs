//! MPI-like communication layer over the simulated fabric.
//!
//! The paper's MPISort uses MPI point-to-point + collectives through
//! MPI.jl, transparently picking GPUDirect ("NVLink Transfer") or
//! host-staged ("CPU Transfer") paths. This module reproduces that
//! surface: typed send/recv, barrier, bcast, gather, allgather,
//! alltoallv and allreduce over rank threads, with every message really
//! moving bytes between threads and the link model charging simulated
//! time per hop (cluster::topology).
//!
//! Byte/message counters are recorded per link kind — the paper claims
//! SIHSort uses "the least amount of MPI communication" of non-IO sorts,
//! and `mpisort` tests assert our implementation's message complexity.
//!
//! The fabric is bounded and fallible (DESIGN.md §16): per-link credit
//! caps give real backpressure, every blocking wait carries a deadline,
//! and a seeded [`FaultPlan`] can drop/delay/partition links or
//! kill/stall ranks deterministically. All send/recv surfaces return
//! [`crate::session::AkResult`] — the old panicking API is gone.

pub mod collectives;
pub mod fabric;
pub mod fault;
pub mod hb;
pub mod wire;

pub use collectives::ReduceOp;
pub use fabric::{CommStats, CommTuning, Endpoint, Fabric, FabricCtl, FaultCounters, TrySend};
pub use hb::{HbState, VClock, Wait};
pub use fault::{FaultPlan, FaultRule, FaultState, RetryPolicy};
pub use wire::{bytes_to_vec, vec_to_bytes};
