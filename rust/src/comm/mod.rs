//! MPI-like communication layer over the simulated fabric.
//!
//! The paper's MPISort uses MPI point-to-point + collectives through
//! MPI.jl, transparently picking GPUDirect ("NVLink Transfer") or
//! host-staged ("CPU Transfer") paths. This module reproduces that
//! surface: typed send/recv, barrier, bcast, gather, allgather,
//! alltoallv and allreduce over rank threads, with every message really
//! moving bytes between threads and the link model charging simulated
//! time per hop (cluster::topology).
//!
//! Byte/message counters are recorded per link kind — the paper claims
//! SIHSort uses "the least amount of MPI communication" of non-IO sorts,
//! and `mpisort` tests assert our implementation's message complexity.

pub mod collectives;
pub mod fabric;
pub mod wire;

pub use collectives::ReduceOp;
pub use fabric::{CommStats, Endpoint, Fabric};
pub use wire::{bytes_to_vec, vec_to_bytes};
