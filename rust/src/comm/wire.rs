//! Byte-level (de)serialisation of key vectors — "what goes on the wire".
//!
//! MPI moves untyped buffers; so do we. Keys are `Copy` + `'static` plain
//! data, so the conversion is a memcpy (native endianness: both ends are
//! the same process, as in shared-fabric MPI).

use crate::dtype::SortKey;

/// Serialize a key slice to bytes (memcpy).
pub fn vec_to_bytes<K: SortKey>(xs: &[K]) -> Vec<u8> {
    let bytes = std::mem::size_of_val(xs);
    let mut out = vec![0u8; bytes];
    // SAFETY: K is Copy plain-old-data; sizes match by construction.
    unsafe {
        std::ptr::copy_nonoverlapping(xs.as_ptr() as *const u8, out.as_mut_ptr(), bytes);
    }
    out
}

/// Deserialize bytes back into keys. Length must be a whole multiple of
/// the key size.
pub fn bytes_to_vec<K: SortKey>(bytes: &[u8]) -> Vec<K> {
    let k = std::mem::size_of::<K>();
    assert_eq!(bytes.len() % k, 0, "wire length {} not multiple of {k}", bytes.len());
    let n = bytes.len() / k;
    let mut out = Vec::with_capacity(n);
    // SAFETY: K is Copy plain-old-data; we copy exactly n*k bytes into
    // freshly reserved capacity then set the length.
    unsafe {
        std::ptr::copy_nonoverlapping(bytes.as_ptr(), out.as_mut_ptr() as *mut u8, n * k);
        out.set_len(n);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_all_dtypes() {
        let a: Vec<i16> = vec![-1, 0, i16::MAX];
        assert_eq!(bytes_to_vec::<i16>(&vec_to_bytes(&a)), a);
        let b: Vec<i128> = vec![i128::MIN, 7, i128::MAX];
        assert_eq!(bytes_to_vec::<i128>(&vec_to_bytes(&b)), b);
        let c: Vec<f64> = vec![-0.0, 1.5, f64::INFINITY];
        let rt = bytes_to_vec::<f64>(&vec_to_bytes(&c));
        assert_eq!(rt.len(), 3);
        assert_eq!(rt[1], 1.5);
        assert!(rt[2].is_infinite());
    }

    #[test]
    fn empty() {
        let e: Vec<i32> = vec![];
        assert!(vec_to_bytes(&e).is_empty());
        assert!(bytes_to_vec::<i32>(&[]).is_empty());
    }

    #[test]
    #[should_panic]
    fn rejects_ragged() {
        bytes_to_vec::<i32>(&[1, 2, 3]);
    }
}
