//! MPI-style collectives built on the point-to-point fabric.
//!
//! `bcast` and `gather` use binomial trees (the MPICH algorithms): a
//! linear root-fan would serialise P-1 α latencies at the leader and
//! destroy the paper's flat weak scaling at small payloads; the tree
//! costs O(log P) rounds, matching real MPI. `alltoallv` is inherently
//! O(P) messages per rank. Every collective advances the same `coll_seq`
//! on every rank so tags can never cross-talk between phases.
//!
//! Every collective is fallible (PR 7): sends go through
//! [`Endpoint::send_retry`] so transient link faults are absorbed by
//! bounded backoff at the sender, and a dead rank or deadline surfaces
//! as a typed [`crate::session::AkError`] instead of a hang or panic.
//! Credit-flow safety: each collective uses any `(src, dst)` link at
//! most once per invocation, and every protocol message is consumed by
//! its target *during* the collective — exhausted credit can therefore
//! stall a sender (until the receiver consumes) but never deadlock it.

use crate::dtype::SortKey;
use crate::obs;
use crate::session::{AkError, AkResult};

use super::fabric::Endpoint;
use super::wire::{bytes_to_vec, vec_to_bytes};

impl Endpoint {
    /// Broadcast bytes from `root` (binomial tree); returns the payload on
    /// every rank.
    pub fn bcast_bytes(&mut self, root: usize, bytes: Vec<u8>) -> AkResult<Vec<u8>> {
        let _span = obs::span1(obs::SpanKind::Collective, "bcast", bytes.len() as u64);
        let tag = self.next_coll_tag();
        let me = self.rank();
        let p = self.nranks();
        let rel = (me + p - root) % p;
        // Receive from the parent (clear the lowest set bit of rel).
        let mut payload = bytes;
        let mut mask = 1usize;
        while mask < p {
            if rel & mask != 0 {
                let src = (me + p - mask) % p;
                payload = self.recv_bytes(src, tag)?;
                break;
            }
            mask <<= 1;
        }
        // Forward to children (descending masks below the break point).
        mask >>= 1;
        while mask > 0 {
            if rel + mask < p {
                let dst = (me + mask) % p;
                self.send_retry(dst, tag, &payload)?;
            }
            mask >>= 1;
        }
        Ok(payload)
    }

    /// Typed broadcast.
    pub fn bcast<K: SortKey>(&mut self, root: usize, xs: Vec<K>) -> AkResult<Vec<K>> {
        Ok(bytes_to_vec(&self.bcast_bytes(root, vec_to_bytes(&xs))?))
    }

    /// Gather per-rank byte payloads at `root` (None elsewhere), indexed
    /// by source rank. Binomial tree: each node accumulates its subtree
    /// into a framed buffer ([u64 src][u64 len][bytes]...) and forwards it
    /// once — O(log P) rounds, same total bytes through the root as the
    /// linear algorithm.
    pub fn gather_bytes(&mut self, root: usize, bytes: Vec<u8>) -> AkResult<Option<Vec<Vec<u8>>>> {
        let _span = obs::span1(obs::SpanKind::Collective, "gather", bytes.len() as u64);
        let tag = self.next_coll_tag();
        let me = self.rank();
        let p = self.nranks();
        let rel = (me + p - root) % p;

        let mut acc = Vec::with_capacity(16 + bytes.len());
        frame_push(&mut acc, me as u64, &bytes);
        drop(bytes);

        let mut mask = 1usize;
        while mask < p {
            if rel & mask != 0 {
                // Send the accumulated subtree to the parent and stop.
                let dst = (me + p - mask) % p;
                self.send_retry(dst, tag, &acc)?;
                return Ok(None);
            }
            if rel + mask < p {
                let src = (me + mask) % p;
                let sub = self.recv_bytes(src, tag)?;
                acc.extend_from_slice(&sub);
            }
            mask <<= 1;
        }
        // Root: unframe into per-source slots.
        let mut out: Vec<Vec<u8>> = vec![Vec::new(); p];
        let mut off = 0usize;
        while off < acc.len() {
            let (src, payload, next) = frame_read(&acc, off);
            out[src as usize] = payload;
            off = next;
        }
        Ok(Some(out))
    }

    /// Typed gather.
    pub fn gather<K: SortKey>(&mut self, root: usize, xs: &[K]) -> AkResult<Option<Vec<Vec<K>>>> {
        Ok(self
            .gather_bytes(root, vec_to_bytes(xs))?
            .map(|vs| vs.iter().map(|b| bytes_to_vec(b)).collect()))
    }

    /// Allgather: every rank ends with every rank's payload (gather at
    /// rank 0 + broadcast of the concatenation with a length header).
    pub fn allgather_bytes(&mut self, bytes: Vec<u8>) -> AkResult<Vec<Vec<u8>>> {
        let _span = obs::span(obs::SpanKind::Collective, "allgather");
        let gathered = self.gather_bytes(0, bytes)?;
        // Pack: [n_ranks × u64 length] + concatenated payloads.
        let packed = if self.rank() == 0 {
            let parts = gathered.ok_or_else(|| {
                AkError::Internal(anyhow::anyhow!("gather returned no payload at the root"))
            })?;
            let mut buf = Vec::new();
            for p in &parts {
                buf.extend_from_slice(&(p.len() as u64).to_le_bytes());
            }
            for p in &parts {
                buf.extend_from_slice(p);
            }
            buf
        } else {
            Vec::new()
        };
        let buf = self.bcast_bytes(0, packed)?;
        let n = self.nranks();
        let mut lens = Vec::with_capacity(n);
        for i in 0..n {
            let mut l = [0u8; 8];
            l.copy_from_slice(&buf[8 * i..8 * (i + 1)]);
            lens.push(u64::from_le_bytes(l) as usize);
        }
        let mut out = Vec::with_capacity(n);
        let mut off = 8 * n;
        for len in lens {
            out.push(buf[off..off + len].to_vec());
            off += len;
        }
        Ok(out)
    }

    /// Typed allgather.
    pub fn allgather<K: SortKey>(&mut self, xs: &[K]) -> AkResult<Vec<Vec<K>>> {
        Ok(self.allgather_bytes(vec_to_bytes(xs))?.iter().map(|b| bytes_to_vec(b)).collect())
    }

    /// All-to-all with variable counts: `parts[d]` goes to rank `d`;
    /// returns what every rank sent to *this* rank, indexed by source.
    /// This is SIHSort's single data-exchange step.
    pub fn alltoallv_bytes(&mut self, parts: Vec<Vec<u8>>) -> AkResult<Vec<Vec<u8>>> {
        assert_eq!(parts.len(), self.nranks());
        let total: usize = parts.iter().map(Vec::len).sum();
        let _span = obs::span1(obs::SpanKind::Collective, "alltoallv", total as u64);
        let tag = self.next_coll_tag();
        let me = self.rank();
        let n = self.nranks();
        let mut out: Vec<Vec<u8>> = vec![Vec::new(); n];
        // Send round-robin starting after self to avoid hot-spotting rank 0.
        let mut parts = parts;
        for step in 0..n {
            let dst = (me + step) % n;
            let payload = std::mem::take(&mut parts[dst]);
            self.send_retry(dst, tag, &payload)?;
        }
        for step in 0..n {
            let src = (me + n - step) % n;
            out[src] = self.recv_bytes(src, tag)?;
        }
        Ok(out)
    }

    /// Typed alltoallv over key vectors.
    pub fn alltoallv<K: SortKey>(&mut self, parts: Vec<Vec<K>>) -> AkResult<Vec<Vec<K>>> {
        let bytes = parts.into_iter().map(|p| vec_to_bytes(&p)).collect();
        Ok(self.alltoallv_bytes(bytes)?.iter().map(|b| bytes_to_vec(b)).collect())
    }

    /// Allreduce on f64 (sum/min/max): gather to 0, fold, broadcast.
    pub fn allreduce_f64(&mut self, x: f64, op: ReduceOp) -> AkResult<f64> {
        let parts = self.gather_bytes(0, x.to_le_bytes().to_vec())?;
        let folded = if let Some(parts) = parts {
            let vals = parts.iter().map(|b| {
                let mut a = [0u8; 8];
                a.copy_from_slice(b);
                f64::from_le_bytes(a)
            });
            match op {
                ReduceOp::Sum => vals.sum(),
                ReduceOp::Min => vals.fold(f64::INFINITY, f64::min),
                ReduceOp::Max => vals.fold(f64::NEG_INFINITY, f64::max),
            }
        } else {
            0.0
        };
        let out = self.bcast_bytes(0, folded.to_le_bytes().to_vec())?;
        let mut a = [0u8; 8];
        a.copy_from_slice(&out);
        Ok(f64::from_le_bytes(a))
    }

    /// Allreduce on u64 counters.
    pub fn allreduce_u64(&mut self, x: u64, op: ReduceOp) -> AkResult<u64> {
        let parts = self.gather_bytes(0, x.to_le_bytes().to_vec())?;
        let folded = if let Some(parts) = parts {
            let vals = parts.iter().map(|b| {
                let mut a = [0u8; 8];
                a.copy_from_slice(b);
                u64::from_le_bytes(a)
            });
            match op {
                ReduceOp::Sum => vals.sum(),
                ReduceOp::Min => vals.min().unwrap_or(0),
                ReduceOp::Max => vals.max().unwrap_or(0),
            }
        } else {
            0
        };
        let out = self.bcast_bytes(0, folded.to_le_bytes().to_vec())?;
        let mut a = [0u8; 8];
        a.copy_from_slice(&out);
        Ok(u64::from_le_bytes(a))
    }
}

/// Reduction operator for `allreduce_*`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReduceOp {
    Sum,
    Min,
    Max,
}

/// Append one `[u64 src][u64 len][bytes]` frame.
fn frame_push(buf: &mut Vec<u8>, src: u64, payload: &[u8]) {
    buf.extend_from_slice(&src.to_le_bytes());
    buf.extend_from_slice(&(payload.len() as u64).to_le_bytes());
    buf.extend_from_slice(payload);
}

/// Read the frame at `off`; returns (src, payload, next offset).
fn frame_read(buf: &[u8], off: usize) -> (u64, Vec<u8>, usize) {
    let mut a = [0u8; 8];
    a.copy_from_slice(&buf[off..off + 8]);
    let src = u64::from_le_bytes(a);
    a.copy_from_slice(&buf[off + 8..off + 16]);
    let len = u64::from_le_bytes(a) as usize;
    let payload = buf[off + 16..off + 16 + len].to_vec();
    (src, payload, off + 16 + len)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cfg::TransferMode;
    use crate::cluster::ClusterSpec;
    use crate::comm::fabric::{CommTuning, Fabric};

    fn run_ranks<F, T>(n: usize, f: F) -> Vec<T>
    where
        F: Fn(Endpoint) -> T + Send + Sync + Clone + 'static,
        T: Send + 'static,
    {
        let eps = Fabric::new(ClusterSpec::baskerville(), TransferMode::GpuDirect, vec![true; n]);
        let handles: Vec<_> = eps
            .into_iter()
            .map(|e| {
                let f = f.clone();
                std::thread::spawn(move || f(e))
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    }

    #[test]
    fn bcast_reaches_everyone() {
        let out = run_ranks(4, |mut e| {
            let payload = if e.rank() == 2 { vec![7i32, 8, 9] } else { vec![] };
            e.bcast::<i32>(2, payload).unwrap()
        });
        for v in out {
            assert_eq!(v, vec![7, 8, 9]);
        }
    }

    #[test]
    fn gather_collects_by_source() {
        let out = run_ranks(3, |mut e| {
            let mine = vec![e.rank() as i64 * 10];
            e.gather::<i64>(0, &mine).unwrap()
        });
        let at_root = out[0].as_ref().unwrap();
        assert_eq!(at_root[0], vec![0]);
        assert_eq!(at_root[1], vec![10]);
        assert_eq!(at_root[2], vec![20]);
        assert!(out[1].is_none());
    }

    #[test]
    fn allgather_everywhere() {
        let out = run_ranks(4, |mut e| {
            let mine = vec![e.rank() as i32; e.rank() + 1]; // ragged sizes
            e.allgather::<i32>(&mine).unwrap()
        });
        for parts in out {
            for (src, p) in parts.iter().enumerate() {
                assert_eq!(p, &vec![src as i32; src + 1]);
            }
        }
    }

    #[test]
    fn alltoallv_routes() {
        let out = run_ranks(3, |mut e| {
            let me = e.rank() as i32;
            // Send [me*10 + dst] to each dst.
            let parts: Vec<Vec<i32>> = (0..3).map(|d| vec![me * 10 + d as i32]).collect();
            e.alltoallv::<i32>(parts).unwrap()
        });
        for (me, parts) in out.iter().enumerate() {
            for (src, p) in parts.iter().enumerate() {
                assert_eq!(p, &vec![src as i32 * 10 + me as i32]);
            }
        }
    }

    #[test]
    fn allreduce_ops() {
        let sums = run_ranks(4, |mut e| e.allreduce_f64(e.rank() as f64, ReduceOp::Sum).unwrap());
        assert!(sums.iter().all(|&s| s == 6.0));
        let maxs = run_ranks(4, |mut e| e.allreduce_u64(e.rank() as u64, ReduceOp::Max).unwrap());
        assert!(maxs.iter().all(|&m| m == 3));
    }

    #[test]
    fn collectives_compose_without_crosstalk() {
        // Two different collectives back-to-back must not steal each
        // other's messages.
        let out = run_ranks(3, |mut e| {
            let a = e.allreduce_u64(1, ReduceOp::Sum).unwrap();
            let b = e.allgather::<i32>(&[e.rank() as i32]).unwrap();
            e.barrier().unwrap();
            let c = e.allreduce_u64(10, ReduceOp::Sum).unwrap();
            (a, b.len(), c)
        });
        for (a, blen, c) in out {
            assert_eq!(a, 3);
            assert_eq!(blen, 3);
            assert_eq!(c, 30);
        }
    }

    #[test]
    fn collectives_survive_a_flaky_link() {
        // A 30%-flaky link inside a 4-rank job: sender-side bounded
        // backoff must absorb every drop (deterministic seed).
        use crate::comm::fault::FaultPlan;
        let faults = FaultPlan::parse("flaky:0:1:0.3", 11).unwrap().state();
        let tuning = CommTuning {
            faults: Some(faults),
            retry: crate::comm::RetryPolicy { max_attempts: 12, ..Default::default() },
            ..CommTuning::default()
        };
        let eps = Fabric::new_with(
            ClusterSpec::baskerville(),
            TransferMode::GpuDirect,
            vec![true; 4],
            tuning,
        );
        let handles: Vec<_> = eps
            .into_iter()
            .map(|mut e| {
                std::thread::spawn(move || {
                    let mut s = 0;
                    for _ in 0..6 {
                        s = e.allreduce_u64(e.rank() as u64 + 1, ReduceOp::Sum).unwrap();
                    }
                    let g = e.allgather::<i64>(&[e.rank() as i64]).unwrap();
                    e.finish();
                    (s, g.len(), e.stats().fault_counters())
                })
            })
            .collect();
        let outs: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        for (s, glen, _) in &outs {
            assert_eq!(*s, 10);
            assert_eq!(*glen, 4);
        }
        // The seed is chosen so the link actually dropped something.
        assert!(outs[0].2.dropped > 0, "flaky link never fired: {:?}", outs[0].2);
        assert_eq!(outs[0].2.retries, outs[0].2.dropped);
    }
}
