//! Run records + paper-style table/series rendering.
//!
//! Each distributed-sort run produces a [`SortRunRecord`] with the phase
//! breakdown and fabric statistics; the figure benches collect them into
//! [`Series`] and print the same rows/curves the paper plots (weak/strong
//! scaling, max-throughput bars, cost-normalised times). CSV dumps land in
//! `target/bench-results/` for external plotting.

use std::fmt::Write as _;
use std::io::Write as _;

use crate::cfg::{RunConfig, Sorter, TransferMode};
use crate::obs::{CounterSnapshot, FABRIC_COUNTERS};
use crate::util::{fmt_bytes, fmt_duration, fmt_throughput};

/// Outcome of one distributed sort run (simulated times — see
/// `cluster::devmodel` for the calibration story).
#[derive(Clone, Debug)]
pub struct SortRunRecord {
    /// Paper-legend label, e.g. `GG-AK/Int32`.
    pub label: String,
    /// Number of simulated ranks.
    pub ranks: usize,
    /// Total bytes sorted across all ranks.
    pub total_bytes: usize,
    /// Simulated end-to-end makespan (seconds).
    pub sim_total: f64,
    /// Phase breakdown (simulated seconds, max over ranks).
    pub sim_local_sort: f64,
    pub sim_splitters: f64,
    pub sim_exchange: f64,
    pub sim_final: f64,
    /// Fabric statistics.
    pub messages: u64,
    pub wire_bytes: u64,
    /// Fault/flow counters, summed over driver restart attempts
    /// (DESIGN.md §16, §18): the registered [`FABRIC_COUNTERS`] —
    /// sends that blocked on exhausted link credit, sender-side
    /// retries, deadline/fault timeouts, messages eaten by injected
    /// link faults, and in-process recoveries (restart attempts that
    /// went on to finish the job). Carried as a registry snapshot so
    /// consumers iterate the names instead of enumerating fields.
    pub fabric: CounterSnapshot,
    /// Wall-clock the host actually spent (for the §Perf log).
    pub wall_secs: f64,
}

impl SortRunRecord {
    /// Sends that blocked on exhausted link credit.
    pub fn credit_stalls(&self) -> u64 {
        self.fabric.get("credit_stalls")
    }

    /// Sender-side retries after transient link faults.
    pub fn retries(&self) -> u64 {
        self.fabric.get("retries")
    }

    /// Deadline/fault timeouts.
    pub fn timeouts(&self) -> u64 {
        self.fabric.get("timeouts")
    }

    /// Messages eaten by injected link faults.
    pub fn dropped(&self) -> u64 {
        self.fabric.get("dropped")
    }

    /// In-process driver restarts that went on to finish the job.
    pub fn recoveries(&self) -> u64 {
        self.fabric.get("recoveries")
    }
    /// Sorting throughput in the paper's unit (GB sorted / simulated s).
    pub fn throughput_bps(&self) -> f64 {
        if self.sim_total <= 0.0 {
            return 0.0;
        }
        self.total_bytes as f64 / self.sim_total
    }

    pub fn row(&self) -> String {
        let mut row = format!(
            "{:<22} ranks={:<4} {:>10}  t={:>10}  [sort {} | split {} | xchg {} | final {}]  {:>14}  msgs={} wire={}",
            self.label,
            self.ranks,
            fmt_bytes(self.total_bytes as f64),
            fmt_duration(self.sim_total),
            fmt_duration(self.sim_local_sort),
            fmt_duration(self.sim_splitters),
            fmt_duration(self.sim_exchange),
            fmt_duration(self.sim_final),
            fmt_throughput(self.throughput_bps()),
            self.messages,
            fmt_bytes(self.wire_bytes as f64),
        );
        if self.fabric.any_nonzero() {
            let _ = write!(row, " faults[{}]", self.fabric.render_nonzero());
        }
        row
    }
}

/// Paper-legend label for a configuration: `GG-AK`, `GC-TR`, `CC-JB`, ...
pub fn legend(sorter: Sorter, transfer: TransferMode) -> String {
    format!("{}-{}", transfer.prefix(sorter), sorter.code())
}

/// Label including dtype, e.g. `GG-AK/Int32`.
pub fn legend_dtype(cfg: &RunConfig) -> String {
    format!("{}/{}", legend(cfg.sorter, cfg.transfer), cfg.dtype.paper_name())
}

/// A named (x, y) curve, e.g. ranks → GB/s.
#[derive(Clone, Debug, Default)]
pub struct Series {
    /// Legend name of the curve.
    pub name: String,
    /// The (x, y) points, in insertion order.
    pub points: Vec<(f64, f64)>,
}

impl Series {
    /// New empty series with a legend name.
    pub fn new(name: impl Into<String>) -> Self {
        Self { name: name.into(), points: Vec::new() }
    }

    /// Append one (x, y) point.
    pub fn push(&mut self, x: f64, y: f64) {
        self.points.push((x, y));
    }
}

/// Render aligned series as a text table: one row per x, one column per
/// series (the paper's figures as text).
pub fn render_series_table(title: &str, x_label: &str, y_label: &str, series: &[Series]) -> String {
    let mut xs: Vec<f64> = series.iter().flat_map(|s| s.points.iter().map(|p| p.0)).collect();
    xs.sort_by(|a, b| a.total_cmp(b));
    xs.dedup();
    let mut out = String::new();
    let _ = writeln!(out, "\n== {title} ==  ({y_label} by {x_label})");
    let _ = write!(out, "{:>12}", x_label);
    for s in series {
        let _ = write!(out, " {:>16}", s.name);
    }
    let _ = writeln!(out);
    for x in xs {
        let _ = write!(out, "{x:>12.4}");
        for s in series {
            match s.points.iter().find(|p| p.0 == x) {
                Some((_, y)) => {
                    let _ = write!(out, " {y:>16.6}");
                }
                None => {
                    let _ = write!(out, " {:>16}", "-");
                }
            }
        }
        let _ = writeln!(out);
    }
    out
}

/// Write series to `target/bench-results/<file>.csv` (long format:
/// series,x,y) for external plotting. Errors are reported, not fatal.
pub fn dump_csv(file: &str, series: &[Series]) {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("target/bench-results");
    if let Err(e) = std::fs::create_dir_all(&dir) {
        eprintln!("warn: cannot create {}: {e}", dir.display());
        return;
    }
    let path = dir.join(format!("{file}.csv"));
    let mut body = String::from("series,x,y\n");
    for s in series {
        for (x, y) in &s.points {
            let _ = writeln!(body, "{},{x},{y}", s.name);
        }
    }
    match std::fs::File::create(&path).and_then(|mut f| f.write_all(body.as_bytes())) {
        Ok(()) => eprintln!("  wrote {}", path.display()),
        Err(e) => eprintln!("warn: writing {}: {e}", path.display()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dtype::ElemType;

    #[test]
    fn legend_codes() {
        assert_eq!(legend(Sorter::Ak, TransferMode::GpuDirect), "GG-AK");
        assert_eq!(legend(Sorter::ThrustRadix, TransferMode::CpuStaged), "GC-TR");
        assert_eq!(legend(Sorter::JuliaBase, TransferMode::CpuStaged), "CC-JB");
        let mut cfg = RunConfig::default();
        cfg.dtype = ElemType::I64;
        assert!(legend_dtype(&cfg).ends_with("/Int64"));
    }

    #[test]
    fn throughput_math() {
        let rec = SortRunRecord {
            label: "x".into(),
            ranks: 4,
            total_bytes: 8_000_000_000,
            sim_total: 2.0,
            sim_local_sort: 1.0,
            sim_splitters: 0.1,
            sim_exchange: 0.7,
            sim_final: 0.2,
            messages: 10,
            wire_bytes: 100,
            fabric: CounterSnapshot::zeroed(&FABRIC_COUNTERS),
            wall_secs: 30.0,
        };
        assert_eq!(rec.throughput_bps(), 4e9);
        assert!(rec.row().contains("GB/s"));
        // Fault counters stay out of the row unless something fired.
        assert!(!rec.row().contains("faults["));
        let mut faulted = rec.clone();
        faulted.fabric.set("retries", 3);
        faulted.fabric.set("recoveries", 1);
        assert_eq!(faulted.retries(), 3);
        assert_eq!(faulted.recoveries(), 1);
        assert!(faulted.row().contains("retries=3"));
        assert!(faulted.row().contains("recoveries=1"));
    }

    #[test]
    fn series_table_aligns() {
        let mut a = Series::new("A");
        a.push(1.0, 10.0);
        a.push(2.0, 20.0);
        let mut b = Series::new("B");
        b.push(2.0, 200.0);
        let t = render_series_table("T", "x", "y", &[a, b]);
        assert!(t.contains("T"));
        assert!(t.contains('-')); // missing point marker
        assert!(t.lines().count() >= 4);
    }
}
