//! Device operations: typed wrappers over the AOT artifact catalog.
//!
//! `DeviceKey` is the per-dtype bridge between `SortKey` and the XLA
//! literal machinery; i128 reports `XLA = false` and every device call on
//! it falls back to the caller's native path (DESIGN.md §2: XLA-CPU has
//! no s128 — the vendor-special-casing effect the paper measures in
//! Fig 2, here in its most extreme form).
//!
//! All entry points handle size-class padding internally: sorts pad with
//! the dtype maximum, scans/reduces with the op identity, and requests
//! beyond the largest class are chunked and recombined on the host
//! (k-way merge for sorts, fold for reduces, carry propagation for
//! scans) — the standard out-of-core pattern for bounded device memory.

use xla::Literal;

use crate::dtype::SortKey;
use crate::runtime::{lit_from_slice, lit_scalar, lit_to_vec, Registry};

/// Per-dtype device capability + literal conversions.
///
/// Every device key is also its own degenerate streaming record
/// (`StreamRecord<Key = Self>`, `PAYLOAD_BYTES = 0`), so the whole
/// scalar surface flows through the record-generic spill/merge layers
/// unchanged (DESIGN.md §19).
pub trait DeviceKey: SortKey + crate::stream::StreamRecord<Key = Self> {
    /// Does an XLA artifact family exist for this dtype?
    const XLA: bool;
    /// Pack a slice into a rank-1 XLA literal.
    fn to_literal(xs: &[Self]) -> anyhow::Result<Literal>;
    /// Pack one value into a rank-0 (scalar) XLA literal — predicate
    /// thresholds and kernel constants ride in this way.
    fn to_scalar_literal(x: Self) -> anyhow::Result<Literal>;
    /// Unpack a rank-1 XLA literal back into a vector.
    fn from_literal(lit: &Literal) -> anyhow::Result<Vec<Self>>;
}

macro_rules! device_key {
    ($ty:ty) => {
        impl DeviceKey for $ty {
            const XLA: bool = true;
            fn to_literal(xs: &[Self]) -> anyhow::Result<Literal> {
                lit_from_slice(xs)
            }
            fn to_scalar_literal(x: Self) -> anyhow::Result<Literal> {
                lit_scalar(x)
            }
            fn from_literal(lit: &Literal) -> anyhow::Result<Vec<Self>> {
                lit_to_vec(lit)
            }
        }
    };
}

device_key!(i16);
device_key!(i32);
device_key!(i64);
device_key!(f32);
device_key!(f64);

impl DeviceKey for i128 {
    const XLA: bool = false;
    fn to_literal(_: &[Self]) -> anyhow::Result<Literal> {
        anyhow::bail!("i128 has no XLA artifact family (s128 unsupported by XLA-CPU)")
    }
    fn to_scalar_literal(_: Self) -> anyhow::Result<Literal> {
        anyhow::bail!("i128 has no XLA artifact family")
    }
    fn from_literal(_: &Literal) -> anyhow::Result<Vec<Self>> {
        anyhow::bail!("i128 has no XLA artifact family")
    }
}

/// Typed device operations bound to an artifact [`Registry`].
#[derive(Clone)]
pub struct DeviceOps {
    reg: Registry,
}

impl DeviceOps {
    pub fn new(reg: Registry) -> Self {
        Self { reg }
    }

    pub fn registry(&self) -> &Registry {
        &self.reg
    }

    /// Sort ascending on the device. Pads to the selected size class with
    /// the dtype max; shards larger than the largest class are sorted in
    /// chunks and k-way merged on the host.
    pub fn sort<K: DeviceKey>(&self, xs: &mut [K]) -> anyhow::Result<()> {
        self.sort_blocked(xs, None)
    }

    /// [`DeviceOps::sort`] with an explicit chunk granule: `block_size`
    /// (the `Launch` knob) caps the artifact size class one device call
    /// covers, so a large shard streams through the engine in
    /// `ceil(n / class(block_size))` calls with a host k-way merge —
    /// bounding per-call device memory exactly like the out-of-core
    /// path does beyond the largest class.
    pub fn sort_blocked<K: DeviceKey>(
        &self,
        xs: &mut [K],
        block_size: Option<usize>,
    ) -> anyhow::Result<()> {
        anyhow::ensure!(K::XLA, "dtype {} not device-supported", K::ELEM);
        let n = xs.len();
        if n <= 1 {
            return Ok(());
        }
        let plan_n = block_size.map(|b| b.clamp(1, n)).unwrap_or(n);
        let plan = self.reg.plan("sort", K::ELEM, plan_n)?;
        let cap = plan.chunk_capacity();
        if n <= cap {
            let sorted = self.sort_chunk(&xs[..], cap)?;
            xs.copy_from_slice(&sorted[..n]);
            return Ok(());
        }
        // Out-of-core / blocked: sort class-sized chunks, merge on host.
        let mut runs: Vec<Vec<K>> = Vec::with_capacity(n.div_ceil(cap));
        for chunk in xs.chunks(cap) {
            let mut sorted = self.sort_chunk(chunk, cap)?;
            sorted.truncate(chunk.len());
            runs.push(sorted);
        }
        let refs: Vec<&[K]> = runs.iter().map(|r| r.as_slice()).collect();
        // Sequential recombine on purpose: this runs in *device* context,
        // which may execute concurrently with the host pool (hybrid
        // co-sort) — fanning out to the default host width here would
        // steal the cores the host shard owns and skew calibration's
        // host:device ratio (DESIGN.md §10/§11).
        let mut merged = Vec::new();
        crate::dtype::resize_for_overwrite(&mut merged, n);
        crate::baselines::kmerge::kmerge_into_slice(&refs, &mut merged);
        xs.copy_from_slice(&merged);
        Ok(())
    }

    fn sort_chunk<K: DeviceKey>(&self, xs: &[K], cap: usize) -> anyhow::Result<Vec<K>> {
        let name = artifact_name("sort", K::ELEM, cap);
        let mut padded = xs.to_vec();
        padded.resize(cap, K::max_key());
        let out = self.reg.runtime().execute(&name, &[K::to_literal(&padded)?])?;
        K::from_literal(&out[0])
    }

    /// Key-value sort (payloads i32). Returns sorted (keys, vals).
    /// Single-class only: callers chunk at a higher level if needed.
    pub fn sort_pairs<K: DeviceKey>(
        &self,
        keys: &[K],
        vals: &[i32],
    ) -> anyhow::Result<(Vec<K>, Vec<i32>)> {
        anyhow::ensure!(K::XLA, "dtype {} not device-supported", K::ELEM);
        anyhow::ensure!(keys.len() == vals.len());
        let n = keys.len();
        let plan = self.reg.plan("sort_pairs", K::ELEM, n)?;
        anyhow::ensure!(
            plan.chunks == 1,
            "sort_pairs request {n} exceeds largest class {}",
            plan.chunk_capacity()
        );
        let cap = plan.chunk_capacity();
        let mut pk = keys.to_vec();
        pk.resize(cap, K::max_key());
        let mut pv = vals.to_vec();
        pv.resize(cap, i32::MAX);
        let out = self.reg.runtime().execute(
            &artifact_name("sort_pairs", K::ELEM, cap),
            &[K::to_literal(&pk)?, lit_from_slice(&pv)?],
        )?;
        let mut k = K::from_literal(&out[0])?;
        let mut v = lit_to_vec::<i32>(&out[1])?;
        k.truncate(n);
        v.truncate(n);
        Ok((k, v))
    }

    /// Inclusive or exclusive prefix-sum on the device (chunked with host
    /// carry propagation beyond the largest class).
    pub fn scan_add<K: DeviceKey + std::ops::Add<Output = K> + Default>(
        &self,
        xs: &[K],
        inclusive: bool,
    ) -> anyhow::Result<Vec<K>> {
        anyhow::ensure!(K::XLA, "dtype {} not device-supported", K::ELEM);
        let n = xs.len();
        if n == 0 {
            return Ok(Vec::new());
        }
        let op = if inclusive { "scan_add_incl" } else { "scan_add_excl" };
        let plan = self.reg.plan(op, K::ELEM, n)?;
        let cap = plan.chunk_capacity();
        let mut out: Vec<K> = Vec::with_capacity(n);
        let mut carry = K::default();
        for chunk in xs.chunks(cap) {
            // Always compute the inclusive scan per chunk; exclusivity is
            // applied when emitting (shift by one with the carry).
            let mut padded = chunk.to_vec();
            padded.resize(cap, K::default());
            let res = self.reg.runtime().execute(
                &artifact_name("scan_add_incl", K::ELEM, cap),
                &[K::to_literal(&padded)?],
            )?;
            let scanned = K::from_literal(&res[0])?;
            if inclusive {
                out.extend(scanned[..chunk.len()].iter().map(|&v| v + carry));
            } else {
                out.push(carry);
                out.extend(scanned[..chunk.len() - 1].iter().map(|&v| v + carry));
            }
            carry = carry + scanned[chunk.len() - 1];
        }
        Ok(out)
    }

    /// Scalar reduction on the device. `op` in {add, min, max}; pads with
    /// the op identity; chunks fold on the host.
    pub fn reduce<K: DeviceKey>(
        &self,
        xs: &[K],
        op: &str,
        identity: K,
        fold: impl Fn(K, K) -> K,
    ) -> anyhow::Result<K> {
        anyhow::ensure!(K::XLA, "dtype {} not device-supported", K::ELEM);
        if xs.is_empty() {
            return Ok(identity);
        }
        let family = format!("reduce_{op}");
        let plan = self.reg.plan(&family, K::ELEM, xs.len())?;
        let cap = plan.chunk_capacity();
        let mut acc = identity;
        for chunk in xs.chunks(cap) {
            let mut padded = chunk.to_vec();
            padded.resize(cap, identity);
            let res = self
                .reg
                .runtime()
                .execute(&artifact_name(&family, K::ELEM, cap), &[K::to_literal(&padded)?])?;
            let v = K::from_literal(&res[0])?;
            acc = fold(acc, v[0]);
        }
        Ok(acc)
    }

    /// `switch_below` variant: device computes per-tile partials, the host
    /// finishes the fold (paper §II-B: skips a device-side tree pass +
    /// sync when n is small enough that launch overhead dominates).
    pub fn reduce_partials_add<K: DeviceKey + std::ops::Add<Output = K> + Default>(
        &self,
        xs: &[K],
    ) -> anyhow::Result<K> {
        anyhow::ensure!(K::XLA, "dtype {} not device-supported", K::ELEM);
        if xs.is_empty() {
            return Ok(K::default());
        }
        let plan = self.reg.plan("reduce_partials_add", K::ELEM, xs.len())?;
        let cap = plan.chunk_capacity();
        let mut acc = K::default();
        for chunk in xs.chunks(cap) {
            let mut padded = chunk.to_vec();
            padded.resize(cap, K::default());
            let res = self.reg.runtime().execute(
                &artifact_name("reduce_partials_add", K::ELEM, cap),
                &[K::to_literal(&padded)?],
            )?;
            let parts = K::from_literal(&res[0])?;
            acc = parts.into_iter().fold(acc, |a, b| a + b);
        }
        Ok(acc)
    }

    /// Insertion indices of `needles` into sorted `haystack` on device.
    /// side: "first" (lower_bound) or "last" (upper_bound).
    pub fn searchsorted<K: DeviceKey>(
        &self,
        haystack: &[K],
        needles: &[K],
        side: &str,
    ) -> anyhow::Result<Vec<u32>> {
        anyhow::ensure!(K::XLA, "dtype {} not device-supported", K::ELEM);
        anyhow::ensure!(side == "first" || side == "last");
        let family = format!("searchsorted_{side}");
        let plan = self.reg.plan(&family, K::ELEM, haystack.len())?;
        anyhow::ensure!(
            plan.chunks == 1,
            "haystack {} exceeds largest searchsorted class {}",
            haystack.len(),
            plan.chunk_capacity()
        );
        let cap = plan.chunk_capacity();
        let info = &plan.artifact;
        let needle_cap = info.needles.unwrap_or(1024);
        let mut hay = haystack.to_vec();
        hay.resize(cap, K::max_key());
        let hay_lit = K::to_literal(&hay)?;
        let exe = self.reg.runtime().get(&info.name)?;

        let mut out = Vec::with_capacity(needles.len());
        for chunk in needles.chunks(needle_cap) {
            let mut nd = chunk.to_vec();
            nd.resize(needle_cap, K::max_key());
            let res = self
                .reg
                .runtime()
                .execute_compiled(&exe, &[hay_lit.clone(), K::to_literal(&nd)?])?;
            let idx = lit_to_vec::<i32>(&res[0])?;
            // Clamp: padded sentinel lanes in the haystack tail must not
            // be counted as real insertion slots.
            out.extend(idx[..chunk.len()].iter().map(|&i| (i as usize).min(haystack.len()) as u32));
        }
        Ok(out)
    }

    /// Radial Basis Function kernel over `(3, n)` packed coordinates.
    pub fn rbf_f32(&self, pts: &[f32]) -> anyhow::Result<Vec<f32>> {
        self.elemwise_3n("rbf", pts, None)
    }

    /// LJG potential over two `(3, n)` position arrays + runtime consts.
    pub fn ljg_f32(&self, p1: &[f32], p2: &[f32], consts: [f32; 4]) -> anyhow::Result<Vec<f32>> {
        anyhow::ensure!(p1.len() == p2.len());
        self.elemwise_3n("ljg", p1, Some((p2, consts)))
    }

    fn elemwise_3n(
        &self,
        op: &str,
        p1: &[f32],
        extra: Option<(&[f32], [f32; 4])>,
    ) -> anyhow::Result<Vec<f32>> {
        anyhow::ensure!(p1.len() % 3 == 0, "(3, n) layout required");
        let n = p1.len() / 3;
        let plan = self.reg.plan(op, crate::dtype::ElemType::F32, n)?;
        let cap = plan.chunk_capacity();
        let exe = self.reg.runtime().get(&plan.artifact.name)?;
        let mut out = Vec::with_capacity(n);
        let mut start = 0usize;
        while start < n {
            let len = cap.min(n - start);
            // Repack [x.., y.., z..] rows for this window, padded to cap.
            let mut buf = vec![0.0f32; 3 * cap];
            for row in 0..3 {
                buf[row * cap..row * cap + len]
                    .copy_from_slice(&p1[row * n + start..row * n + start + len]);
            }
            let mut inputs =
                vec![crate::runtime::lit_from_slice_2d(&buf, 3, cap)?];
            if let Some((p2, consts)) = extra {
                let mut buf2 = vec![0.0f32; 3 * cap];
                for row in 0..3 {
                    buf2[row * cap..row * cap + len]
                        .copy_from_slice(&p2[row * n + start..row * n + start + len]);
                }
                // Padded lanes: p1 == p2 == 0 -> r = 0 -> sigma/r = inf; keep
                // them finite by separating the pads (x offset on p2).
                for pad in len..cap {
                    buf2[pad] = 1.0;
                }
                inputs.push(crate::runtime::lit_from_slice_2d(&buf2, 3, cap)?);
                inputs.push(lit_from_slice(&consts)?);
            }
            let res = self.reg.runtime().execute_compiled(&exe, &inputs)?;
            let v = lit_to_vec::<f32>(&res[0])?;
            out.extend_from_slice(&v[..len]);
            start += len;
        }
        Ok(out)
    }

    /// Chunked early-exit `any(x > t)` — the paper's two-algorithm design:
    /// the device evaluates a conservative chunk predicate, the host stops
    /// at the first hit. Generic over every dtype with an `any_gt`
    /// artifact family (gate with `registry().supports("any_gt", ...)`);
    /// padding uses the dtype minimum, which can never satisfy `x > t`.
    pub fn any_gt<K: DeviceKey>(&self, xs: &[K], threshold: K) -> anyhow::Result<bool> {
        anyhow::ensure!(K::XLA, "dtype {} not device-supported", K::ELEM);
        let plan = self.reg.plan("any_gt", K::ELEM, xs.len())?;
        let cap = plan.chunk_capacity();
        let exe = self.reg.runtime().get(&plan.artifact.name)?;
        for chunk in xs.chunks(cap) {
            let mut padded = chunk.to_vec();
            padded.resize(cap, K::min_key());
            let res = self.reg.runtime().execute_compiled(
                &exe,
                &[K::to_literal(&padded)?, K::to_scalar_literal(threshold)?],
            )?;
            if lit_to_vec::<i32>(&res[0])?[0] != 0 {
                return Ok(true); // early exit: remaining chunks never run
            }
        }
        Ok(false)
    }

    /// Chunked early-exit `all(x > t)`; padding uses the dtype maximum,
    /// which satisfies `x > t` whenever any real element could.
    pub fn all_gt<K: DeviceKey>(&self, xs: &[K], threshold: K) -> anyhow::Result<bool> {
        anyhow::ensure!(K::XLA, "dtype {} not device-supported", K::ELEM);
        let plan = self.reg.plan("all_gt", K::ELEM, xs.len())?;
        let cap = plan.chunk_capacity();
        let exe = self.reg.runtime().get(&plan.artifact.name)?;
        for chunk in xs.chunks(cap) {
            let mut padded = chunk.to_vec();
            padded.resize(cap, K::max_key());
            let res = self.reg.runtime().execute_compiled(
                &exe,
                &[K::to_literal(&padded)?, K::to_scalar_literal(threshold)?],
            )?;
            if lit_to_vec::<i32>(&res[0])?[0] == 0 {
                return Ok(false);
            }
        }
        Ok(true)
    }
}

/// `{op}_{dtype}_n{log2 n}` — must match `python/compile/aot.py`.
pub fn artifact_name(op: &str, dtype: crate::dtype::ElemType, n: usize) -> String {
    debug_assert!(n.is_power_of_two());
    format!("{op}_{}_n{}", dtype.name(), n.trailing_zeros())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn artifact_names_match_catalog_convention() {
        use crate::dtype::ElemType;
        assert_eq!(artifact_name("sort", ElemType::I32, 1024), "sort_i32_n10");
        assert_eq!(artifact_name("scan_add_incl", ElemType::F64, 1 << 17), "scan_add_incl_f64_n17");
    }

    #[test]
    fn i128_reports_unsupported() {
        assert!(!<i128 as DeviceKey>::XLA);
        assert!(i128::to_literal(&[1i128]).is_err());
    }
}
