//! Scoped std-thread data parallelism (rayon is unavailable offline).
//!
//! Mirrors the paper's `foreachindex` CPU path: static partitioning of
//! the index space over a fixed thread count (the paper uses 10 threads;
//! here the count is a parameter and the default adapts to the host).

/// Default thread count (paper uses 10; capped by available parallelism).
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).clamp(1, 10)
}

/// Split `len` into `parts` contiguous ranges of near-equal size.
pub fn split_ranges(len: usize, parts: usize) -> Vec<std::ops::Range<usize>> {
    let parts = parts.max(1).min(len.max(1));
    let base = len / parts;
    let extra = len % parts;
    let mut out = Vec::with_capacity(parts);
    let mut start = 0;
    for i in 0..parts {
        let sz = base + usize::from(i < extra);
        out.push(start..start + sz);
        start += sz;
    }
    out
}

/// Run `f(chunk_index, &mut chunk)` over disjoint chunks of `xs` on
/// `threads` scoped threads.
pub fn parallel_chunks<T: Send, F>(xs: &mut [T], threads: usize, f: F)
where
    F: Fn(usize, &mut [T]) + Sync,
{
    let len = xs.len();
    if threads <= 1 || len < 2 {
        f(0, xs);
        return;
    }
    let ranges = split_ranges(len, threads);
    std::thread::scope(|s| {
        let mut rest = xs;
        for (i, r) in ranges.iter().enumerate() {
            let (head, tail) = rest.split_at_mut(r.len());
            rest = tail;
            let f = &f;
            s.spawn(move || f(i, head));
        }
    });
}

/// Run `f(chunk_index, &mut chunk, &mut scratch_chunk)` over disjoint
/// chunks of `xs` and the *same-ranged* chunks of `scratch` on scoped
/// threads. Both slices must have equal length; chunk `i` of `xs` and
/// chunk `i` of `scratch` cover identical index ranges, so a worker can
/// move data between its pair without synchronisation (the merge engine's
/// parallel copy-back uses exactly this — `baselines::merge_path`).
pub fn parallel_chunks_with_scratch<T: Send, U: Send, F>(
    xs: &mut [T],
    scratch: &mut [U],
    threads: usize,
    f: F,
) where
    F: Fn(usize, &mut [T], &mut [U]) + Sync,
{
    assert_eq!(xs.len(), scratch.len(), "xs/scratch length mismatch");
    let len = xs.len();
    if threads <= 1 || len < 2 {
        f(0, xs, scratch);
        return;
    }
    let ranges = split_ranges(len, threads);
    std::thread::scope(|s| {
        let mut rest = xs;
        let mut rest_s = scratch;
        for (i, r) in ranges.iter().enumerate() {
            let (head, tail) = rest.split_at_mut(r.len());
            rest = tail;
            let (head_s, tail_s) = rest_s.split_at_mut(r.len());
            rest_s = tail_s;
            let f = &f;
            s.spawn(move || f(i, head, head_s));
        }
    });
}

/// Run `f(range)` for each partition of `0..len` on scoped threads and
/// collect the per-chunk results in order.
pub fn parallel_for_each_chunk<R: Send, F>(len: usize, threads: usize, f: F) -> Vec<R>
where
    F: Fn(std::ops::Range<usize>) -> R + Sync,
{
    let ranges = split_ranges(len, threads);
    if ranges.len() <= 1 {
        return ranges.into_iter().map(f).collect();
    }
    let mut out: Vec<Option<R>> = (0..ranges.len()).map(|_| None).collect();
    std::thread::scope(|s| {
        for (slot, r) in out.iter_mut().zip(ranges.into_iter()) {
            let f = &f;
            s.spawn(move || *slot = Some(f(r)));
        }
    });
    out.into_iter().map(|o| o.expect("worker panicked")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ranges_cover_exactly() {
        for (len, parts) in [(10usize, 3usize), (7, 7), (5, 10), (0, 4), (100, 1)] {
            let rs = split_ranges(len, parts);
            let total: usize = rs.iter().map(|r| r.len()).sum();
            assert_eq!(total, len, "len={len} parts={parts}");
            for w in rs.windows(2) {
                assert_eq!(w[0].end, w[1].start);
            }
        }
    }

    #[test]
    fn chunks_see_disjoint_data() {
        let mut xs = vec![0u32; 1000];
        parallel_chunks(&mut xs, 4, |i, chunk| {
            for v in chunk.iter_mut() {
                *v = i as u32 + 1;
            }
        });
        assert!(xs.iter().all(|&v| (1..=4).contains(&v)));
        // First and last chunks touched.
        assert_eq!(xs[0], 1);
        assert_eq!(*xs.last().unwrap(), 4);
    }

    #[test]
    fn for_each_chunk_ordered_results() {
        let sums = parallel_for_each_chunk(100, 3, |r| r.sum::<usize>());
        let total: usize = sums.iter().sum();
        assert_eq!(total, (0..100).sum::<usize>());
        assert_eq!(sums.len(), 3);
    }

    #[test]
    fn chunks_with_scratch_pair_same_ranges() {
        let mut xs: Vec<u64> = (0..1000).collect();
        let mut scratch = vec![0u64; 1000];
        // Workers copy their xs chunk into the paired scratch chunk.
        parallel_chunks_with_scratch(&mut xs, &mut scratch, 4, |_, src, dst| {
            dst.copy_from_slice(src);
        });
        assert_eq!(scratch, (0..1000).collect::<Vec<u64>>());
        // Single-thread and empty degenerate paths.
        let mut a = vec![1u8; 3];
        let mut b = vec![0u8; 3];
        parallel_chunks_with_scratch(&mut a, &mut b, 1, |_, src, dst| dst.copy_from_slice(src));
        assert_eq!(b, vec![1u8; 3]);
        let mut e1: Vec<u8> = vec![];
        let mut e2: Vec<u8> = vec![];
        parallel_chunks_with_scratch(&mut e1, &mut e2, 4, |_, _, _| {});
    }

    #[test]
    fn degenerate_thread_counts() {
        let mut xs = vec![1i32; 8];
        parallel_chunks(&mut xs, 0, |_, c| c.iter_mut().for_each(|v| *v += 1));
        assert!(xs.iter().all(|&v| v == 2));
        let r = parallel_for_each_chunk(0, 4, |r| r.len());
        assert_eq!(r.iter().sum::<usize>(), 0);
    }
}
