//! Execution backends for the algorithm suite.
//!
//! The paper dispatches on array type: CPU arrays hit Julia Base /
//! threaded code, GPU arrays hit the transpiled kernels. Here the same
//! API dispatches on [`Backend`]:
//!
//! * `Native` — single thread, idiomatic Rust ("Julia Base" / "C" rows of
//!   Table II).
//! * `Threaded(n)` — std-thread data parallelism ("C OpenMP" / AK-CPU
//!   rows).
//! * `Device` — the AOT Pallas/XLA artifacts through PJRT (the "AK GPU"
//!   rows); per-dtype support is static via [`device::DeviceKey`], with
//!   i128 falling back to native paths under the device model
//!   (DESIGN.md §2).
//! * `Hybrid` — cost-model-driven CPU–GPU co-processing: host thread
//!   pool and device engine execute disjoint shards of one call
//!   concurrently (`crate::hybrid`, DESIGN.md §10).

pub mod device;
pub mod threaded;

pub use device::{DeviceKey, DeviceOps};
pub use threaded::{parallel_chunks, parallel_chunks_with_scratch, parallel_for_each_chunk};

use crate::hybrid::HybridEngine;
use crate::runtime::Registry;

/// Which engine executes an algorithm call.
#[derive(Clone)]
pub enum Backend {
    /// Single-thread host execution.
    Native,
    /// Host execution over `n` std threads.
    Threaded(usize),
    /// AOT artifact execution through PJRT.
    Device(DeviceOps),
    /// CPU–GPU co-processing: both engines at once, split by a
    /// [`crate::hybrid::HybridPlan`] (DESIGN.md §10).
    Hybrid(HybridEngine),
}

impl Backend {
    /// Device backend over an artifact registry.
    pub fn device(reg: Registry) -> Backend {
        Backend::Device(DeviceOps::new(reg))
    }

    /// Hybrid backend over a prepared engine (see
    /// [`crate::hybrid::HybridEngine`]).
    pub fn hybrid(engine: HybridEngine) -> Backend {
        Backend::Hybrid(engine)
    }

    /// Short human-readable engine name.
    pub fn name(&self) -> String {
        match self {
            Backend::Native => "native".to_string(),
            Backend::Threaded(n) => format!("threaded({n})"),
            Backend::Device(_) => "device".to_string(),
            Backend::Hybrid(h) => h.describe(),
        }
    }

    /// The device engine handle, when one is attached (directly or
    /// inside a hybrid engine).
    pub fn device_ops(&self) -> Option<&DeviceOps> {
        match self {
            Backend::Device(d) => Some(d),
            Backend::Hybrid(h) => h.device.as_ref(),
            _ => None,
        }
    }

    /// The artifact registry, when a device engine is attached.
    pub fn registry(&self) -> Option<&Registry> {
        self.device_ops().map(|d| d.registry())
    }
}

impl std::fmt::Debug for Backend {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.name())
    }
}
