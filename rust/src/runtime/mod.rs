//! The PJRT runtime: loads AOT artifacts (HLO text lowered by
//! `python/compile/aot.py`) and executes them on the CPU PJRT client.
//!
//! This is the "runs native after transpilation" half of the paper's
//! architecture: the Julia→PTX/AIR pipeline becomes JAX/Pallas→HLO→PJRT,
//! with Rust owning the request path. One compiled executable per
//! (op, dtype, size-class) artifact, compiled on first use and cached.

pub mod client;
pub mod literal;
pub mod manifest;
pub mod registry;

pub use client::{Executable, Runtime};
pub use literal::{lit_from_slice, lit_from_slice_2d, lit_scalar, lit_to_vec};
pub use manifest::{ArtifactInfo, Manifest, TensorSpec};
pub use registry::Registry;
