//! PJRT client + executable cache.
//!
//! `Runtime` owns one CPU PJRT client and a lazily-populated cache of
//! compiled executables, one per artifact. Execution is serialised by a
//! device lock: the simulated cluster's ranks all time their own compute
//! with logical clocks (cluster::SimClock), so device-level serialisation
//! does not distort the reported numbers — it models a shared accelerator
//! work queue on this 1-core testbed.

use std::collections::HashMap;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::Context;
use xla::Literal;

use super::manifest::{ArtifactInfo, Manifest};

/// A compiled artifact ready to execute.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    /// Manifest entry this executable was compiled from.
    pub info: ArtifactInfo,
    /// XLA compile time (first-use cost; reported by `akbench info`).
    pub compile_secs: f64,
}

/// Cumulative runtime counters (picked up by `metrics`).
#[derive(Debug, Default)]
pub struct RuntimeStats {
    pub compiles: AtomicU64,
    pub executes: AtomicU64,
    pub exec_nanos: AtomicU64,
}

/// The PJRT runtime. Create once (per process) with [`Runtime::open`];
/// cheap to share via `Arc`.
pub struct Runtime {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: Mutex<HashMap<String, Arc<Executable>>>,
    /// Device work-queue lock (see module docs).
    exec_lock: Mutex<()>,
    pub stats: RuntimeStats,
}

// SAFETY: the PJRT C API is documented thread-safe; the `xla` crate only
// omits the markers because it wraps raw pointers. All mutation of the
// cache map is behind a Mutex, and `execute` is serialised by `exec_lock`.
unsafe impl Send for Runtime {}
unsafe impl Sync for Runtime {}

impl Runtime {
    /// Open the artifact directory (manifest + PJRT CPU client).
    pub fn open(dir: &Path) -> anyhow::Result<Arc<Runtime>> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu()
            .map_err(anyhow::Error::from)
            .context("creating PJRT CPU client")?;
        Ok(Arc::new(Runtime {
            client,
            manifest,
            cache: Mutex::new(HashMap::new()),
            exec_lock: Mutex::new(()),
            stats: RuntimeStats::default(),
        }))
    }

    /// Open `artifacts/` at the default location (see [`crate::artifacts_dir`]).
    pub fn open_default() -> anyhow::Result<Arc<Runtime>> {
        Self::open(&crate::artifacts_dir())
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Get (compiling on first use) the executable for an artifact name.
    pub fn get(&self, name: &str) -> anyhow::Result<Arc<Executable>> {
        if let Some(e) = self.cache.lock().unwrap().get(name) {
            return Ok(e.clone());
        }
        let info = self
            .manifest
            .get(name)
            .with_context(|| format!("unknown artifact '{name}' (re-run `make artifacts`?)"))?
            .clone();
        let path = self.manifest.path_of(&info);
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(anyhow::Error::from)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(anyhow::Error::from)
            .with_context(|| format!("XLA-compiling artifact '{name}'"))?;
        let compiled = Arc::new(Executable { exe, info, compile_secs: t0.elapsed().as_secs_f64() });
        self.stats.compiles.fetch_add(1, Ordering::Relaxed);
        let mut cache = self.cache.lock().unwrap();
        Ok(cache.entry(name.to_string()).or_insert(compiled).clone())
    }

    /// Execute an artifact by name. Inputs must match the manifest specs
    /// (padding is the caller's job — see `runtime::registry` and
    /// `algorithms`). Returns the flattened output literals (the AOT side
    /// lowers with `return_tuple=True`; the tuple is decomposed here).
    pub fn execute(&self, name: &str, inputs: &[Literal]) -> anyhow::Result<Vec<Literal>> {
        let exe = self.get(name)?;
        self.execute_compiled(&exe, inputs)
    }

    /// Execute an already-resolved executable (hot path: no name lookup).
    pub fn execute_compiled(
        &self,
        exe: &Executable,
        inputs: &[Literal],
    ) -> anyhow::Result<Vec<Literal>> {
        anyhow::ensure!(
            inputs.len() == exe.info.inputs.len(),
            "artifact '{}' expects {} inputs, got {}",
            exe.info.name,
            exe.info.inputs.len(),
            inputs.len()
        );
        let _guard = self.exec_lock.lock().unwrap();
        let t0 = Instant::now();
        let result = exe
            .exe
            .execute::<Literal>(inputs)
            .map_err(anyhow::Error::from)
            .with_context(|| format!("executing artifact '{}'", exe.info.name))?;
        let mut tuple = result[0][0]
            .to_literal_sync()
            .map_err(anyhow::Error::from)
            .context("fetching result literal")?;
        let outs = tuple
            .decompose_tuple()
            .map_err(anyhow::Error::from)
            .context("decomposing result tuple")?;
        self.stats.executes.fetch_add(1, Ordering::Relaxed);
        self.stats
            .exec_nanos
            .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        anyhow::ensure!(
            outs.len() == exe.info.outputs.len(),
            "artifact '{}' returned {} outputs, expected {}",
            exe.info.name,
            outs.len(),
            exe.info.outputs.len()
        );
        Ok(outs)
    }

    /// Names of all artifacts currently compiled into the cache.
    pub fn cached_names(&self) -> Vec<String> {
        let mut v: Vec<String> = self.cache.lock().unwrap().keys().cloned().collect();
        v.sort();
        v
    }
}
