//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! Rust runtime. Parsed from `artifacts/manifest.json` with the in-repo
//! JSON parser.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context};

use crate::dtype::ElemType;
use crate::util::json::Json;

/// Shape + dtype of one artifact input/output tensor.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: ElemType,
}

impl TensorSpec {
    pub fn element_count(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One AOT-lowered HLO module.
#[derive(Clone, Debug)]
pub struct ArtifactInfo {
    /// Unique name, `{op}_{dtype}_n{log2n}`.
    pub name: String,
    /// HLO text file, relative to the artifact directory.
    pub file: String,
    /// Operation family (`sort`, `scan_add_incl`, `searchsorted_first`, ...).
    pub op: String,
    /// Primary element dtype.
    pub dtype: ElemType,
    /// Size class: the static primary-input length this module was lowered
    /// for (callers pad up to it).
    pub n: usize,
    /// Needle-block length for `searchsorted_*` artifacts.
    pub needles: Option<usize>,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// The parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    /// Pallas tile length the artifacts were built with.
    pub tile: usize,
    pub artifacts: Vec<ArtifactInfo>,
    by_name: HashMap<String, usize>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> anyhow::Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts` first)", path.display()))?;
        Self::parse(dir, &text)
    }

    /// Parse manifest text (separated from IO for tests).
    pub fn parse(dir: &Path, text: &str) -> anyhow::Result<Manifest> {
        let j = Json::parse(text).context("parsing manifest.json")?;
        let version = j.get("version").as_usize().unwrap_or(0);
        if version != 1 {
            bail!("unsupported manifest version {version}");
        }
        let tile = j.get("tile").as_usize().context("manifest: missing tile")?;
        let mut artifacts = Vec::new();
        let mut by_name = HashMap::new();
        for (idx, a) in j
            .get("artifacts")
            .as_arr()
            .context("manifest: missing artifacts")?
            .iter()
            .enumerate()
        {
            let name = a
                .get("name")
                .as_str()
                .with_context(|| format!("artifact #{idx}: missing name"))?
                .to_string();
            let dtype_s = a.get("dtype").as_str().unwrap_or("");
            let dtype = ElemType::parse(dtype_s)
                .with_context(|| format!("artifact {name}: bad dtype '{dtype_s}'"))?;
            let info = ArtifactInfo {
                file: a
                    .get("file")
                    .as_str()
                    .with_context(|| format!("artifact {name}: missing file"))?
                    .to_string(),
                op: a
                    .get("op")
                    .as_str()
                    .with_context(|| format!("artifact {name}: missing op"))?
                    .to_string(),
                dtype,
                n: a.get("n").as_usize().with_context(|| format!("artifact {name}: missing n"))?,
                needles: a.get("needles").as_usize(),
                inputs: parse_specs(a.get("inputs")).with_context(|| format!("artifact {name}: inputs"))?,
                outputs: parse_specs(a.get("outputs")).with_context(|| format!("artifact {name}: outputs"))?,
                name: name.clone(),
            };
            if by_name.insert(name.clone(), artifacts.len()).is_some() {
                bail!("duplicate artifact name {name}");
            }
            artifacts.push(info);
        }
        Ok(Manifest { dir: dir.to_path_buf(), tile, artifacts, by_name })
    }

    pub fn get(&self, name: &str) -> Option<&ArtifactInfo> {
        self.by_name.get(name).map(|&i| &self.artifacts[i])
    }

    /// All artifacts of one op family, sorted by ascending size class.
    pub fn family(&self, op: &str, dtype: ElemType) -> Vec<&ArtifactInfo> {
        let mut v: Vec<&ArtifactInfo> = self
            .artifacts
            .iter()
            .filter(|a| a.op == op && a.dtype == dtype)
            .collect();
        v.sort_by_key(|a| a.n);
        v
    }

    /// Absolute path of an artifact's HLO text file.
    pub fn path_of(&self, info: &ArtifactInfo) -> PathBuf {
        self.dir.join(&info.file)
    }
}

fn parse_specs(j: &Json) -> anyhow::Result<Vec<TensorSpec>> {
    let arr = j.as_arr().context("expected array of tensor specs")?;
    let mut out = Vec::with_capacity(arr.len());
    for s in arr {
        let shape = s
            .get("shape")
            .as_arr()
            .context("tensor spec: missing shape")?
            .iter()
            .map(|d| d.as_usize().context("tensor spec: bad dim"))
            .collect::<anyhow::Result<Vec<usize>>>()?;
        let dt = s.get("dtype").as_str().context("tensor spec: missing dtype")?;
        let dtype = ElemType::parse(dt).with_context(|| format!("tensor spec: bad dtype '{dt}'"))?;
        out.push(TensorSpec { shape, dtype });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1, "tile": 1024,
      "artifacts": [
        {"name": "sort_i32_n10", "file": "sort_i32_n10.hlo.txt",
         "op": "sort", "dtype": "i32", "n": 1024,
         "inputs": [{"shape": [1024], "dtype": "i32"}],
         "outputs": [{"shape": [1024], "dtype": "i32"}]},
        {"name": "sort_i32_n14", "file": "sort_i32_n14.hlo.txt",
         "op": "sort", "dtype": "i32", "n": 16384,
         "inputs": [{"shape": [16384], "dtype": "i32"}],
         "outputs": [{"shape": [16384], "dtype": "i32"}]},
        {"name": "searchsorted_first_i32_n10",
         "file": "s.hlo.txt", "op": "searchsorted_first", "dtype": "i32",
         "n": 1024, "needles": 1024,
         "inputs": [{"shape": [1024], "dtype": "i32"},
                    {"shape": [1024], "dtype": "i32"}],
         "outputs": [{"shape": [1024], "dtype": "i32"}]}
      ]
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(Path::new("/tmp/a"), SAMPLE).unwrap();
        assert_eq!(m.tile, 1024);
        assert_eq!(m.artifacts.len(), 3);
        let a = m.get("sort_i32_n10").unwrap();
        assert_eq!(a.n, 1024);
        assert_eq!(a.dtype, ElemType::I32);
        assert_eq!(a.inputs[0].element_count(), 1024);
        assert_eq!(m.get("searchsorted_first_i32_n10").unwrap().needles, Some(1024));
    }

    #[test]
    fn family_sorted_by_size() {
        let m = Manifest::parse(Path::new("/tmp/a"), SAMPLE).unwrap();
        let fam = m.family("sort", ElemType::I32);
        assert_eq!(fam.len(), 2);
        assert!(fam[0].n < fam[1].n);
        assert!(m.family("sort", ElemType::I64).is_empty());
    }

    #[test]
    fn rejects_bad_version() {
        assert!(Manifest::parse(Path::new("/t"), r#"{"version": 9, "tile": 1, "artifacts": []}"#).is_err());
    }

    #[test]
    fn rejects_duplicates() {
        let dup = SAMPLE.replace("sort_i32_n14", "sort_i32_n10");
        assert!(Manifest::parse(Path::new("/t"), &dup).is_err());
    }
}
