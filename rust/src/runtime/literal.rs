//! Typed helpers around `xla::Literal` for host<->device data movement.
//!
//! The xla crate's `NativeType` covers only {i32,i64,u32,u64,f32,f64};
//! `create_from_shape_and_untyped_data` + `ArrayElement` covers every
//! dtype we need (notably i16), so all constructors here go through the
//! untyped-bytes path.

use anyhow::Context;
use xla::{ArrayElement, Literal};

/// Build a rank-1 literal from a typed slice.
pub fn lit_from_slice<T: ArrayElement>(xs: &[T]) -> anyhow::Result<Literal> {
    lit_from_bytes::<T>(xs, &[xs.len()])
}

/// Build a rank-2 literal (row-major `dims = [d0, d1]`).
pub fn lit_from_slice_2d<T: ArrayElement>(xs: &[T], d0: usize, d1: usize) -> anyhow::Result<Literal> {
    anyhow::ensure!(xs.len() == d0 * d1, "shape mismatch: {} != {d0}x{d1}", xs.len());
    lit_from_bytes::<T>(xs, &[d0, d1])
}

/// Build a rank-0 (scalar) literal.
pub fn lit_scalar<T: ArrayElement>(x: T) -> anyhow::Result<Literal> {
    lit_from_bytes::<T>(std::slice::from_ref(&x), &[])
}

fn lit_from_bytes<T: ArrayElement>(xs: &[T], dims: &[usize]) -> anyhow::Result<Literal> {
    // SAFETY: `xs` is a live, initialised slice of plain-old-data
    // scalars (every `ArrayElement` here is one); viewing it as bytes
    // covers exactly `size_of_val(xs)` bytes of the same allocation,
    // and the borrow keeps it alive for the view's lifetime.
    let bytes = unsafe {
        std::slice::from_raw_parts(xs.as_ptr() as *const u8, std::mem::size_of_val(xs))
    };
    Literal::create_from_shape_and_untyped_data(T::TY, dims, bytes)
        .map_err(anyhow::Error::from)
        .context("creating literal")
}

/// Copy a literal's data out as a typed vector.
pub fn lit_to_vec<T: ArrayElement>(lit: &Literal) -> anyhow::Result<Vec<T>> {
    lit.to_vec::<T>().map_err(anyhow::Error::from).context("reading literal")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_i16() {
        let xs: Vec<i16> = vec![-3, 0, 7, i16::MAX, i16::MIN];
        let lit = lit_from_slice(&xs).unwrap();
        assert_eq!(lit_to_vec::<i16>(&lit).unwrap(), xs);
    }

    #[test]
    fn roundtrip_f64() {
        let xs: Vec<f64> = vec![1.5, -2.25, 0.0];
        let lit = lit_from_slice(&xs).unwrap();
        assert_eq!(lit_to_vec::<f64>(&lit).unwrap(), xs);
    }

    #[test]
    fn scalar() {
        let lit = lit_scalar(42i32).unwrap();
        assert_eq!(lit_to_vec::<i32>(&lit).unwrap(), vec![42]);
    }

    #[test]
    fn rank2() {
        let xs: Vec<f32> = (0..6).map(|i| i as f32).collect();
        let lit = lit_from_slice_2d(&xs, 2, 3).unwrap();
        assert_eq!(lit_to_vec::<f32>(&lit).unwrap(), xs);
        assert!(lit_from_slice_2d(&xs, 2, 2).is_err());
    }
}
