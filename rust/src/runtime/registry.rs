//! Size-class selection: map a dynamic problem size onto the fixed-shape
//! artifact catalog.
//!
//! XLA executables have static shapes, so the AOT catalog is lowered at
//! power-of-two size classes and callers pad up: ascending sorts pad with
//! the dtype maximum (sentinels sink to the tail and are truncated),
//! scans/reduces pad with the op identity. When a request exceeds the
//! largest class the caller chunks and combines natively (e.g.
//! `Session::sort` k-way-merges sorted chunks) — the same strategy a
//! real deployment uses to bound device memory.

use std::sync::Arc;

use anyhow::Context;

use super::client::Runtime;
use super::manifest::ArtifactInfo;
use crate::dtype::ElemType;

/// Artifact lookup helper bound to a [`Runtime`].
#[derive(Clone)]
pub struct Registry {
    rt: Arc<Runtime>,
}

impl Registry {
    pub fn new(rt: Arc<Runtime>) -> Self {
        Self { rt }
    }

    pub fn runtime(&self) -> &Arc<Runtime> {
        &self.rt
    }

    /// Smallest size class of `op`/`dtype` with capacity >= n, if any.
    pub fn class_for(&self, op: &str, dtype: ElemType, n: usize) -> Option<ArtifactInfo> {
        self.rt
            .manifest()
            .family(op, dtype)
            .into_iter()
            .find(|a| a.n >= n)
            .cloned()
    }

    /// Largest available size class of `op`/`dtype` (chunking granule).
    pub fn largest_class(&self, op: &str, dtype: ElemType) -> Option<ArtifactInfo> {
        self.rt.manifest().family(op, dtype).into_iter().last().cloned()
    }

    /// Resolve `op`/`dtype`/`n` to (artifact, chunking plan): if `n` fits a
    /// class, one chunk of that class; otherwise ceil(n / largest) chunks
    /// of the largest class.
    pub fn plan(&self, op: &str, dtype: ElemType, n: usize) -> anyhow::Result<ExecPlan> {
        if let Some(a) = self.class_for(op, dtype, n) {
            return Ok(ExecPlan { artifact: a, chunks: 1 });
        }
        let a = self
            .largest_class(op, dtype)
            .with_context(|| format!("no '{op}' artifacts for dtype {dtype} (is i128? see DESIGN.md §2)"))?;
        let chunks = n.div_ceil(a.n);
        Ok(ExecPlan { artifact: a, chunks })
    }

    /// Whether any artifact family exists for this op/dtype at all.
    pub fn supports(&self, op: &str, dtype: ElemType) -> bool {
        !self.rt.manifest().family(op, dtype).is_empty()
    }
}

/// Result of [`Registry::plan`].
#[derive(Clone, Debug)]
pub struct ExecPlan {
    pub artifact: ArtifactInfo,
    /// Number of artifact invocations needed to cover the request.
    pub chunks: usize,
}

impl ExecPlan {
    /// Per-chunk capacity in elements.
    pub fn chunk_capacity(&self) -> usize {
        self.artifact.n
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::Manifest;
    use std::path::Path;

    // Registry logic is pure over the manifest; test selection against a
    // synthetic manifest without touching PJRT.
    fn manifest() -> Manifest {
        Manifest::parse(
            Path::new("/tmp/x"),
            r#"{
              "version": 1, "tile": 1024,
              "artifacts": [
                {"name": "sort_i32_n10", "file": "a", "op": "sort", "dtype": "i32", "n": 1024,
                 "inputs": [{"shape": [1024], "dtype": "i32"}], "outputs": [{"shape": [1024], "dtype": "i32"}]},
                {"name": "sort_i32_n14", "file": "b", "op": "sort", "dtype": "i32", "n": 16384,
                 "inputs": [{"shape": [16384], "dtype": "i32"}], "outputs": [{"shape": [16384], "dtype": "i32"}]}
              ]
            }"#,
        )
        .unwrap()
    }

    #[test]
    fn selects_smallest_fitting_class() {
        let m = manifest();
        let fam = m.family("sort", ElemType::I32);
        assert_eq!(fam.iter().find(|a| a.n >= 500).unwrap().n, 1024);
        assert_eq!(fam.iter().find(|a| a.n >= 1025).unwrap().n, 16384);
        assert!(fam.iter().find(|a| a.n >= 20000).is_none());
    }
}
