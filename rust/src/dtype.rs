//! Element types and sortable-key abstractions.
//!
//! The paper benchmarks sorting over Int16/Int32/Int64/Int128/Float32/
//! Float64 (Figs 2–4). [`ElemType`] is the runtime tag used by configs,
//! the artifact registry and the metrics tables; [`SortKey`] is the
//! static-dispatch trait the algorithms and SIHSort are generic over.
//!
//! Int128 note: XLA-CPU has no s128, so `i128` routes to the native
//! backends only (DESIGN.md §2) — exactly the situation the paper
//! describes where vendor libraries special-case small types and lose
//! their edge on big ones.

use std::fmt;

/// Runtime element-type tag (the paper's benchmarked dtypes).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum ElemType {
    /// 16-bit signed integer.
    I16,
    /// 32-bit signed integer.
    I32,
    /// 64-bit signed integer.
    I64,
    /// 128-bit signed integer (native-only: no XLA `s128`, DESIGN.md §2).
    I128,
    /// 32-bit float.
    F32,
    /// 64-bit float.
    F64,
}

impl ElemType {
    /// All dtypes from the paper's Figures 2–4.
    pub const ALL: [ElemType; 6] = [
        ElemType::I16,
        ElemType::I32,
        ElemType::I64,
        ElemType::I128,
        ElemType::F32,
        ElemType::F64,
    ];

    /// Size in bytes of one element.
    pub fn size_bytes(self) -> usize {
        match self {
            ElemType::I16 => 2,
            ElemType::I32 | ElemType::F32 => 4,
            ElemType::I64 | ElemType::F64 => 8,
            ElemType::I128 => 16,
        }
    }

    /// Manifest / CLI name (`i32`, `f64`, ...).
    pub fn name(self) -> &'static str {
        match self {
            ElemType::I16 => "i16",
            ElemType::I32 => "i32",
            ElemType::I64 => "i64",
            ElemType::I128 => "i128",
            ElemType::F32 => "f32",
            ElemType::F64 => "f64",
        }
    }

    /// Paper-style display name (`Int32`, `Float64`, ...).
    pub fn paper_name(self) -> &'static str {
        match self {
            ElemType::I16 => "Int16",
            ElemType::I32 => "Int32",
            ElemType::I64 => "Int64",
            ElemType::I128 => "Int128",
            ElemType::F32 => "Float32",
            ElemType::F64 => "Float64",
        }
    }

    pub fn parse(s: &str) -> Option<ElemType> {
        match s.to_ascii_lowercase().as_str() {
            "i16" | "int16" => Some(ElemType::I16),
            "i32" | "int32" => Some(ElemType::I32),
            "i64" | "int64" => Some(ElemType::I64),
            "i128" | "int128" => Some(ElemType::I128),
            "f32" | "float32" => Some(ElemType::F32),
            "f64" | "float64" => Some(ElemType::F64),
            _ => None,
        }
    }

    /// Whether an XLA artifact family exists for this dtype (i128 is
    /// native-only; see module docs).
    pub fn xla_supported(self) -> bool {
        !matches!(self, ElemType::I128)
    }
}

impl fmt::Display for ElemType {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// A totally-ordered, radix-decomposable sort key. Implemented for the
/// six paper dtypes; everything generic in `algorithms`, `baselines` and
/// `mpisort` dispatches statically through this trait.
pub trait SortKey: Copy + Send + Sync + PartialOrd + fmt::Debug + 'static {
    /// Runtime tag for this type.
    const ELEM: ElemType;

    /// Unsigned image of the key: `a <= b  <=>  to_bits(a) <= to_bits(b)`.
    /// (For floats this is the standard sign-flip total-order transform,
    /// i.e. IEEE-754 totalOrder on non-NaN values.) Radix sort and the
    /// histogram splitter interpolation both run on this image.
    fn to_bits(self) -> u128;

    /// Inverse of [`SortKey::to_bits`].
    fn from_bits(bits: u128) -> Self;

    /// Number of significant bytes in the bit image.
    const KEY_BYTES: usize;

    /// Total-order comparison (floats: NaN-safe via the bit image).
    #[inline]
    fn cmp_total(&self, other: &Self) -> std::cmp::Ordering {
        self.to_bits().cmp(&other.to_bits())
    }

    /// Maximum key (ascending-sort padding sentinel — matches the Python
    /// AOT side's `sort_sentinel`).
    fn max_key() -> Self;

    /// Minimum key.
    fn min_key() -> Self;

    /// IEEE-comparison canonical form: floats map `-0.0` to `+0.0` (the
    /// one non-NaN case where IEEE `==` and the total order disagree);
    /// everything else — integers, NaN included — is the identity.
    /// [`crate::stream`]'s histogram binning canonicalises edges and
    /// keys through this so a `-0.0` key never lands strictly below a
    /// `0.0` bin edge.
    #[inline]
    fn canon_ieee_zero(self) -> Self {
        self
    }
}

impl SortKey for i16 {
    const ELEM: ElemType = ElemType::I16;
    const KEY_BYTES: usize = 2;
    #[inline]
    fn to_bits(self) -> u128 {
        (self as u16 ^ 0x8000) as u128
    }
    #[inline]
    fn from_bits(bits: u128) -> Self {
        (bits as u16 ^ 0x8000) as i16
    }
    fn max_key() -> Self {
        i16::MAX
    }
    fn min_key() -> Self {
        i16::MIN
    }
}

impl SortKey for i32 {
    const ELEM: ElemType = ElemType::I32;
    const KEY_BYTES: usize = 4;
    #[inline]
    fn to_bits(self) -> u128 {
        (self as u32 ^ 0x8000_0000) as u128
    }
    #[inline]
    fn from_bits(bits: u128) -> Self {
        (bits as u32 ^ 0x8000_0000) as i32
    }
    fn max_key() -> Self {
        i32::MAX
    }
    fn min_key() -> Self {
        i32::MIN
    }
}

impl SortKey for i64 {
    const ELEM: ElemType = ElemType::I64;
    const KEY_BYTES: usize = 8;
    #[inline]
    fn to_bits(self) -> u128 {
        (self as u64 ^ 0x8000_0000_0000_0000) as u128
    }
    #[inline]
    fn from_bits(bits: u128) -> Self {
        (bits as u64 ^ 0x8000_0000_0000_0000) as i64
    }
    fn max_key() -> Self {
        i64::MAX
    }
    fn min_key() -> Self {
        i64::MIN
    }
}

impl SortKey for i128 {
    const ELEM: ElemType = ElemType::I128;
    const KEY_BYTES: usize = 16;
    #[inline]
    fn to_bits(self) -> u128 {
        self as u128 ^ (1u128 << 127)
    }
    #[inline]
    fn from_bits(bits: u128) -> Self {
        (bits ^ (1u128 << 127)) as i128
    }
    fn max_key() -> Self {
        i128::MAX
    }
    fn min_key() -> Self {
        i128::MIN
    }
}

impl SortKey for f32 {
    const ELEM: ElemType = ElemType::F32;
    const KEY_BYTES: usize = 4;
    #[inline]
    fn to_bits(self) -> u128 {
        let b = self.to_bits();
        // Sign-flip transform: negative floats reverse, positives offset.
        let k = if b & 0x8000_0000 != 0 { !b } else { b | 0x8000_0000 };
        k as u128
    }
    #[inline]
    fn from_bits(bits: u128) -> Self {
        let b = bits as u32;
        let r = if b & 0x8000_0000 != 0 { b & 0x7FFF_FFFF } else { !b };
        f32::from_bits(r)
    }
    fn max_key() -> Self {
        f32::INFINITY
    }
    fn min_key() -> Self {
        f32::NEG_INFINITY
    }
    #[inline]
    fn canon_ieee_zero(self) -> Self {
        // `-0.0 == 0.0` under IEEE; NaN compares false and passes through.
        if self == 0.0 {
            0.0
        } else {
            self
        }
    }
}

impl SortKey for f64 {
    const ELEM: ElemType = ElemType::F64;
    const KEY_BYTES: usize = 8;
    #[inline]
    fn to_bits(self) -> u128 {
        let b = self.to_bits();
        let k = if b & 0x8000_0000_0000_0000 != 0 {
            !b
        } else {
            b | 0x8000_0000_0000_0000
        };
        k as u128
    }
    #[inline]
    fn from_bits(bits: u128) -> Self {
        let b = bits as u64;
        let r = if b & 0x8000_0000_0000_0000 != 0 {
            b & 0x7FFF_FFFF_FFFF_FFFF
        } else {
            !b
        };
        f64::from_bits(r)
    }
    fn max_key() -> Self {
        f64::INFINITY
    }
    fn min_key() -> Self {
        f64::NEG_INFINITY
    }
    #[inline]
    fn canon_ieee_zero(self) -> Self {
        if self == 0.0 {
            0.0
        } else {
            self
        }
    }
}

/// Sort a slice by the total order of [`SortKey`] (used by tests and the
/// "Julia Base" single-thread baseline).
pub fn sort_total<K: SortKey>(xs: &mut [K]) {
    xs.sort_unstable_by(|a, b| a.cmp_total(b));
}

/// Resize `out` to exactly `len` slots without initialising them — the
/// one audited home of the scratch-buffer `set_len` idiom the sort
/// engines share (sequential/parallel radix ping-pong buffers, merge
/// scratch, `kmerge_into`'s output). Reuses existing capacity.
///
/// SAFETY rationale: every [`SortKey`] is a plain `Copy` scalar for
/// which any bit pattern is a valid value, and every caller overwrites
/// every slot before the buffer is read (zero-initialising instead
/// costs a measurable extra pass on the hot sort paths).
pub(crate) fn resize_for_overwrite<K: SortKey>(out: &mut Vec<K>, len: usize) {
    out.clear();
    out.reserve(len);
    #[allow(clippy::uninit_vec)]
    // SAFETY: capacity >= len after the reserve; every `SortKey` is a
    // `Copy` scalar valid for any bit pattern, and callers overwrite
    // every slot before reading (the rationale above).
    unsafe {
        out.set_len(len);
    }
}

/// Is the slice ascending under the total order?
pub fn is_sorted_total<K: SortKey>(xs: &[K]) -> bool {
    xs.windows(2).all(|w| w[0].cmp_total(&w[1]) != std::cmp::Ordering::Greater)
}

/// Bit-image equality of two key slices — stricter than `PartialEq`: it
/// distinguishes NaN payloads and −0.0 from +0.0. This is the one
/// comparison rule behind every cross-engine correctness gate (the
/// `bench-sort` divergence check and the parallel-engine test suite).
pub fn bits_eq<K: SortKey>(a: &[K], b: &[K]) -> bool {
    a.len() == b.len() && a.iter().zip(b.iter()).all(|(x, y)| x.to_bits() == y.to_bits())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip<K: SortKey + PartialEq>(xs: &[K]) {
        for &x in xs {
            assert!(K::from_bits(x.to_bits()) == x, "{x:?}");
        }
    }

    fn order_preserved<K: SortKey>(xs: &[K]) {
        for &a in xs {
            for &b in xs {
                let lhs = a.to_bits().cmp(&b.to_bits());
                let rhs = a.partial_cmp(&b).unwrap();
                assert_eq!(lhs, rhs, "{a:?} vs {b:?}");
            }
        }
    }

    #[test]
    fn i16_bits() {
        let xs = [i16::MIN, -2, -1, 0, 1, 2, i16::MAX];
        roundtrip(&xs);
        order_preserved(&xs);
    }

    #[test]
    fn i32_bits() {
        let xs = [i32::MIN, -100, 0, 7, i32::MAX];
        roundtrip(&xs);
        order_preserved(&xs);
    }

    #[test]
    fn i64_bits() {
        let xs = [i64::MIN, -1, 0, 1, i64::MAX];
        roundtrip(&xs);
        order_preserved(&xs);
    }

    #[test]
    fn i128_bits() {
        let xs = [i128::MIN, -(1i128 << 100), -1, 0, 1, 1i128 << 100, i128::MAX];
        roundtrip(&xs);
        order_preserved(&xs);
    }

    #[test]
    fn f32_bits() {
        let xs = [
            f32::NEG_INFINITY,
            -1.0e30,
            -1.0,
            -0.0,
            0.0,
            1.0e-30,
            1.0,
            f32::INFINITY,
        ];
        // -0.0 and 0.0 differ in bit image but not in partial_cmp; check
        // monotonicity on strictly increasing values only.
        let strict: Vec<f32> = xs.iter().copied().filter(|x| *x != 0.0 || x.is_sign_positive()).collect();
        roundtrip(&xs);
        order_preserved(&strict);
        // -0.0 sorts before +0.0 in the total order (IEEE totalOrder).
        assert!((-0.0f32).to_bits_key() < 0.0f32.to_bits_key());
    }

    trait BitsKey {
        fn to_bits_key(self) -> u128;
    }
    impl BitsKey for f32 {
        fn to_bits_key(self) -> u128 {
            SortKey::to_bits(self)
        }
    }

    #[test]
    fn f64_bits() {
        let xs = [f64::NEG_INFINITY, -2.5, 0.0, 3.14, f64::INFINITY];
        roundtrip(&xs);
        order_preserved(&xs);
    }

    #[test]
    fn sentinels_are_extremes() {
        // NB: qualified calls — f64 has an *inherent* `to_bits` (raw IEEE
        // bits) that would otherwise shadow the total-order bit image.
        assert!(SortKey::to_bits(i32::max_key()) >= SortKey::to_bits(12345i32));
        assert!(SortKey::to_bits(f64::min_key()) <= SortKey::to_bits(-1e300f64));
    }

    #[test]
    fn elem_type_parse_names() {
        for e in ElemType::ALL {
            assert_eq!(ElemType::parse(e.name()), Some(e));
            assert_eq!(ElemType::parse(e.paper_name()), Some(e));
        }
        assert_eq!(ElemType::parse("bogus"), None);
    }

    #[test]
    fn sort_total_handles_floats() {
        let mut xs = vec![3.0f32, -1.0, f32::INFINITY, 0.5, f32::NEG_INFINITY];
        sort_total(&mut xs);
        assert!(is_sorted_total(&xs));
        assert_eq!(xs[0], f32::NEG_INFINITY);
        assert_eq!(xs[4], f32::INFINITY);
    }
}
