//! Co-processing entry points: one logical call, two engines running
//! disjoint shards *concurrently* — the paper's CPU–GPU co-sorting
//! composability story executed inside a single rank (DESIGN.md §10).
//!
//! Each entry point splits its input at the [`HybridPlan`]'s fraction,
//! runs the host shard on a std-thread pool while the device shard runs
//! on the AOT artifact engine (or its documented host stand-in), and
//! recombines: merge-path partitioned parallel 2-way merge for sorts
//! (DESIGN.md §11), operator fold for reductions, nothing for index
//! loops. Outputs are bit-identical to the single engine paths —
//! asserted by the proptests.

use crate::algorithms::reduce::{ReduceKind, Reducible};
use crate::backend::{Backend, DeviceKey, DeviceOps};
use crate::baselines::merge_path;

use super::plan::HybridPlan;

/// Minimum input length for engine splitting: below this, thread-spawn
/// and merge overhead beats any overlap win, so the whole call runs on
/// one engine.
pub const MIN_COSPLIT: usize = 8192;

/// The hybrid execution engine: a host thread pool plus a device engine.
#[derive(Clone)]
pub struct HybridEngine {
    /// How work splits between the engines.
    pub plan: HybridPlan,
    /// Host engine width (std threads).
    pub host_threads: usize,
    /// Device engine. `None` degrades the device shard to a single host
    /// thread — the same engine-substitution rule the AK sorter applies
    /// before `make artifacts` (DESIGN.md §2).
    pub device: Option<DeviceOps>,
}

impl HybridEngine {
    /// Build an engine from a plan, a host thread count and an optional
    /// device handle.
    pub fn new(plan: HybridPlan, host_threads: usize, device: Option<DeviceOps>) -> HybridEngine {
        HybridEngine { plan, host_threads: host_threads.max(1), device }
    }

    /// Build from an optional [`Backend`] handle: `Backend::Device` wires
    /// the real device engine, anything else (or `None`) selects the
    /// host stand-in.
    pub fn from_backends(
        plan: HybridPlan,
        host_threads: usize,
        device: Option<Backend>,
    ) -> HybridEngine {
        let device = match device {
            Some(Backend::Device(d)) => Some(d),
            _ => None,
        };
        HybridEngine::new(plan, host_threads, device)
    }

    /// The host-side engine as a dispatchable backend.
    pub fn host_backend(&self) -> Backend {
        Backend::Threaded(self.host_threads.max(1))
    }

    /// The device-side engine as a dispatchable backend (single host
    /// thread when no device is attached — see [`HybridEngine::device`]).
    pub fn device_backend(&self) -> Backend {
        match &self.device {
            Some(d) => Backend::Device(d.clone()),
            None => Backend::Threaded(1),
        }
    }

    /// Human-readable engine summary (used by `Backend::name`).
    pub fn describe(&self) -> String {
        format!(
            "hybrid({:.0}% host, {} threads, {})",
            self.plan.host_fraction * 100.0,
            self.host_threads,
            if self.device.is_some() { "device" } else { "host-sim device" }
        )
    }

    /// Route a call over `n` elements: one engine for small inputs and
    /// degenerate splits, otherwise a concurrent two-engine split. Every
    /// co-processing entry point (and `algorithms::search`) shares this
    /// rule, so device-only plans consistently reach the device engine.
    pub fn route(&self, n: usize) -> CoRoute {
        let split = self.plan.split_index(n);
        if n < MIN_COSPLIT || split == n {
            // Tiny inputs always take the host pool — cheaper than a
            // spawn, regardless of the plan.
            CoRoute::Host
        } else if split == 0 {
            CoRoute::Device
        } else {
            CoRoute::Split(split)
        }
    }
}

/// How a hybrid call routes (see [`HybridEngine::route`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CoRoute {
    /// Whole call on the host pool.
    Host,
    /// Whole call on the device engine.
    Device,
    /// Concurrent split: `[0, i)` host, `[i, n)` device.
    Split(usize),
}

impl std::fmt::Debug for HybridEngine {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.describe())
    }
}

fn join_flat<T>(res: std::thread::Result<anyhow::Result<T>>, who: &str) -> anyhow::Result<T> {
    match res {
        Ok(inner) => inner,
        Err(_) => Err(anyhow::anyhow!("{who} co-processing worker panicked")),
    }
}

/// Hybrid co-sort — the flagship: split at the plan, sort both shards
/// concurrently (host thread pool ∥ device engine), then recombine with
/// the merge-path partitioned parallel merge on the host pool. Output
/// equals `sort_by(cmp_total)` for every dtype and split ratio (total
/// order; NaN-safe for floats).
///
/// ```
/// use accelkern::hybrid::{co_sort, HybridEngine, HybridPlan};
/// let eng = HybridEngine::new(HybridPlan::new(0.5), 2, None);
/// let mut v = vec![5i32, -3, 7, 0, 2, 9, -8, 4];
/// co_sort(&eng, &mut v).unwrap();
/// assert_eq!(v, vec![-8, -3, 0, 2, 4, 5, 7, 9]);
/// ```
pub fn co_sort<K: DeviceKey>(eng: &HybridEngine, xs: &mut [K]) -> anyhow::Result<()> {
    let split = match eng.route(xs.len()) {
        CoRoute::Host => return crate::algorithms::sort(&eng.host_backend(), xs),
        CoRoute::Device => return crate::algorithms::sort(&eng.device_backend(), xs),
        CoRoute::Split(split) => split,
    };
    let host_backend = eng.host_backend();
    let dev_backend = eng.device_backend();
    let (host_half, dev_half) = xs.split_at_mut(split);
    let (host_res, dev_res) = std::thread::scope(|s| {
        let h = s.spawn(move || crate::algorithms::sort(&host_backend, host_half));
        let d = s.spawn(move || crate::algorithms::sort(&dev_backend, dev_half));
        (h.join(), d.join())
    });
    join_flat(host_res, "host")?;
    join_flat(dev_res, "device")?;
    // Recombine on the host pool: merge-path partitioned 2-way merge
    // (DESIGN.md §11) — each of the host threads produces one contiguous
    // segment of the merged output, then the copy-back runs on the same
    // pool, so no recombine sweep caps at one core's bandwidth.
    merge_path::merge_runs_in_place(xs, &[split], eng.host_threads.max(1));
    Ok(())
}

/// Hybrid co-reduce: both engines reduce their shard concurrently, the
/// partials fold on the host. `switch_below` is forwarded to the device
/// shard (paper §II-B's device-sync-masking rule).
pub fn co_reduce<K: Reducible>(
    eng: &HybridEngine,
    xs: &[K],
    kind: ReduceKind,
    switch_below: usize,
) -> anyhow::Result<K> {
    let split = match eng.route(xs.len()) {
        CoRoute::Host => {
            return crate::algorithms::reduce(&eng.host_backend(), xs, kind, switch_below)
        }
        CoRoute::Device => {
            return crate::algorithms::reduce(&eng.device_backend(), xs, kind, switch_below)
        }
        CoRoute::Split(split) => split,
    };
    let host_backend = eng.host_backend();
    let dev_backend = eng.device_backend();
    let (host_half, dev_half) = xs.split_at(split);
    let (host_res, dev_res) = std::thread::scope(|s| {
        let h =
            s.spawn(move || crate::algorithms::reduce(&host_backend, host_half, kind, switch_below));
        let d =
            s.spawn(move || crate::algorithms::reduce(&dev_backend, dev_half, kind, switch_below));
        (h.join(), d.join())
    });
    let a = join_flat(host_res, "host")?;
    let b = join_flat(dev_res, "device")?;
    Ok(K::fold(kind, a, b))
}

/// Hybrid co-foreach: the host shard of the index space runs on the
/// thread pool while the device shard runs on the device engine's
/// `foreachindex` emulation (named-kernel semantics: sequential walk —
/// arbitrary closures cannot cross the AOT boundary, see
/// `algorithms::foreach`). Both shards execute concurrently.
pub fn co_foreachindex<F>(eng: &HybridEngine, len: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    let threads = eng.host_threads.max(1);
    // The foreach "device engine" is always a sequential walk (arbitrary
    // closures cannot cross the AOT boundary), so cap its shard at one
    // worker's share no matter how device-heavy the sort-calibrated plan
    // is — otherwise a device-heavy plan collapses the loop to
    // single-thread throughput.
    let split = eng.plan.split_index(len).max(len.saturating_sub(len / (threads + 1)));
    if len < MIN_COSPLIT || split == len {
        crate::algorithms::foreachindex(&eng.host_backend(), len, f);
        return;
    }
    let fr = &f;
    std::thread::scope(|s| {
        s.spawn(move || {
            crate::backend::parallel_for_each_chunk(split, threads, |r| {
                for i in r {
                    fr(i);
                }
            });
        });
        s.spawn(move || {
            for i in split..len {
                fr(i);
            }
        });
    });
}

/// Mutating hybrid co-foreach over a slice: disjoint halves, host pool ∥
/// device-engine emulation, indices preserved.
pub fn co_foreach_mut<T: Send, F>(eng: &HybridEngine, xs: &mut [T], f: F)
where
    F: Fn(usize, &mut T) + Sync,
{
    let n = xs.len();
    let threads = eng.host_threads.max(1);
    // Same sequential-walk cap as `co_foreachindex`.
    let split = eng.plan.split_index(n).max(n.saturating_sub(n / (threads + 1)));
    if n < MIN_COSPLIT || split == n {
        crate::algorithms::foreach::foreach_mut(&eng.host_backend(), xs, f);
        return;
    }
    let (host_half, dev_half) = xs.split_at_mut(split);
    let fr = &f;
    std::thread::scope(|s| {
        s.spawn(move || {
            let ranges = crate::backend::threaded::split_ranges(host_half.len(), threads);
            crate::backend::parallel_chunks(host_half, threads, |ci, chunk| {
                let base = ranges[ci].start;
                for (j, x) in chunk.iter_mut().enumerate() {
                    fr(base + j, x);
                }
            });
        });
        s.spawn(move || {
            for (j, x) in dev_half.iter_mut().enumerate() {
                fr(split + j, x);
            }
        });
    });
}

/// Hybrid `any(x > t)`: both engines scan their shard concurrently with
/// their own early exit; the results OR.
pub fn co_any_gt(eng: &HybridEngine, xs: &[f32], threshold: f32) -> anyhow::Result<bool> {
    let split = match eng.route(xs.len()) {
        CoRoute::Host => return crate::algorithms::any_gt(&eng.host_backend(), xs, threshold),
        CoRoute::Device => {
            return crate::algorithms::any_gt(&eng.device_backend(), xs, threshold)
        }
        CoRoute::Split(split) => split,
    };
    let host_backend = eng.host_backend();
    let dev_backend = eng.device_backend();
    let (a, b) = xs.split_at(split);
    let (host_res, dev_res) = std::thread::scope(|s| {
        let h = s.spawn(move || crate::algorithms::any_gt(&host_backend, a, threshold));
        let d = s.spawn(move || crate::algorithms::any_gt(&dev_backend, b, threshold));
        (h.join(), d.join())
    });
    Ok(join_flat(host_res, "host")? || join_flat(dev_res, "device")?)
}

/// Hybrid `all(x > t)`: both engines scan concurrently; the results AND.
pub fn co_all_gt(eng: &HybridEngine, xs: &[f32], threshold: f32) -> anyhow::Result<bool> {
    let split = match eng.route(xs.len()) {
        CoRoute::Host => return crate::algorithms::all_gt(&eng.host_backend(), xs, threshold),
        CoRoute::Device => {
            return crate::algorithms::all_gt(&eng.device_backend(), xs, threshold)
        }
        CoRoute::Split(split) => split,
    };
    let host_backend = eng.host_backend();
    let dev_backend = eng.device_backend();
    let (a, b) = xs.split_at(split);
    let (host_res, dev_res) = std::thread::scope(|s| {
        let h = s.spawn(move || crate::algorithms::all_gt(&host_backend, a, threshold));
        let d = s.spawn(move || crate::algorithms::all_gt(&dev_backend, b, threshold));
        (h.join(), d.join())
    });
    Ok(join_flat(host_res, "host")? && join_flat(dev_res, "device")?)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dtype::{is_sorted_total, SortKey};
    use crate::util::Prng;
    use crate::workload::{generate, Distribution, KeyGen};

    fn engine(frac: f64) -> HybridEngine {
        HybridEngine::new(HybridPlan::new(frac), 3, None)
    }

    fn check_cosort<K: KeyGen + PartialEq + DeviceKey>(seed: u64, n: usize) {
        for dist in [
            Distribution::Uniform,
            Distribution::Sorted,
            Distribution::Reverse,
            Distribution::DupHeavy,
        ] {
            let orig: Vec<K> = generate(&mut Prng::new(seed), dist, n);
            let mut want = orig.clone();
            want.sort_by(|a, b| a.cmp_total(b));
            for frac in [0.0, 0.3, 0.5, 0.9, 1.0] {
                let mut got = orig.clone();
                co_sort(&engine(frac), &mut got).unwrap();
                assert!(got == want, "{dist:?} frac={frac} n={n}");
            }
        }
    }

    #[test]
    fn cosort_matches_total_sort_all_dtypes() {
        check_cosort::<i16>(1, 20_000);
        check_cosort::<i32>(2, 20_000);
        check_cosort::<i64>(3, 20_000);
        check_cosort::<i128>(4, 20_000);
        check_cosort::<f32>(5, 20_000);
        check_cosort::<f64>(6, 20_000);
    }

    #[test]
    fn cosort_tiny_and_empty_inputs() {
        for n in [0usize, 1, 2, 5, 100] {
            check_cosort::<i32>(7, n);
        }
    }

    #[test]
    fn cosort_handles_float_specials() {
        let mut xs: Vec<f64> =
            generate(&mut Prng::new(8), Distribution::Uniform, MIN_COSPLIT * 2);
        xs[17] = f64::NAN;
        xs[1234] = f64::INFINITY;
        xs[8888] = f64::NEG_INFINITY;
        xs[9999] = -0.0;
        let mut want = xs.clone();
        want.sort_by(|a, b| a.cmp_total(b));
        let mut got = xs;
        co_sort(&engine(0.4), &mut got).unwrap();
        assert!(is_sorted_total(&got));
        let bits = |v: &[f64]| v.iter().map(|x| x.to_bits()).collect::<Vec<u64>>();
        assert_eq!(bits(&got), bits(&want));
    }

    #[test]
    fn coreduce_matches_host() {
        let xs: Vec<i64> = generate(&mut Prng::new(9), Distribution::Uniform, 30_000);
        let want: i64 = xs.iter().fold(0i64, |a, &b| a.wrapping_add(b));
        for frac in [0.0, 0.5, 1.0] {
            let got = co_reduce(&engine(frac), &xs, ReduceKind::Add, 0).unwrap();
            assert_eq!(got, want, "frac {frac}");
            let mn = co_reduce(&engine(frac), &xs, ReduceKind::Min, 0).unwrap();
            assert_eq!(mn, *xs.iter().min().unwrap());
        }
    }

    #[test]
    fn coforeach_visits_every_index_once() {
        use std::sync::atomic::{AtomicU32, Ordering};
        let n = MIN_COSPLIT + 1000;
        let hits: Vec<AtomicU32> = (0..n).map(|_| AtomicU32::new(0)).collect();
        co_foreachindex(&engine(0.6), n, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn coforeach_mut_copy_kernel() {
        let n = MIN_COSPLIT + 321;
        let src: Vec<u64> = (0..n as u64).collect();
        let mut dst = vec![0u64; n];
        co_foreach_mut(&engine(0.5), &mut dst, |i, d| *d = src[i]);
        assert_eq!(dst, src);
    }

    #[test]
    fn copredicates_or_and_across_shards() {
        let n = MIN_COSPLIT * 2;
        let mut xs = vec![0.0f32; n];
        // Hit only in the device shard at frac 0.5.
        xs[n - 7] = 5.0;
        let eng = engine(0.5);
        assert!(co_any_gt(&eng, &xs, 1.0).unwrap());
        assert!(!co_any_gt(&eng, &xs, 10.0).unwrap());
        assert!(co_all_gt(&eng, &xs, -1.0).unwrap());
        assert!(!co_all_gt(&eng, &xs, 0.5).unwrap());
        // Hit only in the host shard.
        let mut ys = vec![0.0f32; n];
        ys[3] = 5.0;
        assert!(co_any_gt(&eng, &ys, 1.0).unwrap());
    }

    #[test]
    fn engine_describe_mentions_plan() {
        let eng = engine(0.25);
        assert!(eng.describe().contains("25%"));
        assert!(eng.describe().contains("host-sim"));
    }

    #[test]
    fn route_rule_is_shared_and_consistent() {
        // Tiny inputs take the host pool regardless of the plan.
        assert_eq!(engine(0.0).route(100), CoRoute::Host);
        assert_eq!(engine(1.0).route(100), CoRoute::Host);
        // Degenerate fractions route the whole call to the owning engine.
        assert_eq!(engine(0.0).route(MIN_COSPLIT), CoRoute::Device);
        assert_eq!(engine(1.0).route(MIN_COSPLIT), CoRoute::Host);
        // Proper fractions split.
        assert_eq!(engine(0.5).route(MIN_COSPLIT * 2), CoRoute::Split(MIN_COSPLIT));
    }
}
