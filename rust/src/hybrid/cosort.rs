//! Co-processing entry points: one logical call, two engines running
//! disjoint shards *concurrently* — the paper's CPU–GPU co-sorting
//! composability story executed inside a single rank (DESIGN.md §10).
//!
//! Each entry point splits its input at the [`HybridPlan`]'s fraction,
//! runs the host shard on a std-thread pool while the device shard runs
//! on the AOT artifact engine (or its documented host stand-in), and
//! recombines: merge-path partitioned parallel 2-way merge for sorts
//! (DESIGN.md §11), operator fold for reductions, nothing for index
//! loops. Outputs are bit-identical to the single engine paths —
//! asserted by the proptests.
//!
//! The session layer reaches these through the `*_launch` variants,
//! which thread the per-call [`Launch`] knobs into the co-split gate
//! (`prefer_parallel_threshold` overrides [`MIN_COSPLIT`]), the host
//! pool width (`max_tasks` / `min_elems_per_task`) and the device chunk
//! granule (`block_size`).

use crate::algorithms::predicates::host_any;
use crate::algorithms::reduce::{host_reduce, ReduceKind, Reducible};
use crate::algorithms::sort::threaded_sort;
use crate::backend::{Backend, DeviceKey, DeviceOps};
use crate::baselines::merge_path;
use crate::dtype::SortKey;
use crate::session::{AkError, AkResult, Launch, DEFAULT_PAR_THRESHOLD};

use super::plan::HybridPlan;

/// Minimum input length for engine splitting: below this, thread-spawn
/// and merge overhead beats any overlap win, so the whole call runs on
/// one engine. Overridable per call via
/// `Launch::prefer_parallel_threshold`.
pub const MIN_COSPLIT: usize = 8192;

/// The hybrid execution engine: a host thread pool plus a device engine.
#[derive(Clone)]
pub struct HybridEngine {
    /// How work splits between the engines.
    pub plan: HybridPlan,
    /// Host engine width (std threads).
    pub host_threads: usize,
    /// Device engine. `None` degrades the device shard to a single host
    /// thread — the same engine-substitution rule the AK sorter applies
    /// before `make artifacts` (DESIGN.md §2).
    pub device: Option<DeviceOps>,
}

impl HybridEngine {
    /// Build an engine from a plan, a host thread count and an optional
    /// device handle.
    pub fn new(plan: HybridPlan, host_threads: usize, device: Option<DeviceOps>) -> HybridEngine {
        HybridEngine { plan, host_threads: host_threads.max(1), device }
    }

    /// Build from an optional [`Backend`] handle: `Backend::Device` wires
    /// the real device engine, anything else (or `None`) selects the
    /// host stand-in.
    pub fn from_backends(
        plan: HybridPlan,
        host_threads: usize,
        device: Option<Backend>,
    ) -> HybridEngine {
        let device = match device {
            Some(Backend::Device(d)) => Some(d),
            _ => None,
        };
        HybridEngine::new(plan, host_threads, device)
    }

    /// The host-side engine as a dispatchable backend.
    pub fn host_backend(&self) -> Backend {
        Backend::Threaded(self.host_threads.max(1))
    }

    /// The device-side engine as a dispatchable backend (single host
    /// thread when no device is attached — see [`HybridEngine::device`]).
    pub fn device_backend(&self) -> Backend {
        match &self.device {
            Some(d) => Backend::Device(d.clone()),
            None => Backend::Threaded(1),
        }
    }

    /// Human-readable engine summary (used by `Backend::name`).
    pub fn describe(&self) -> String {
        format!(
            "hybrid({:.0}% host, {} threads, {})",
            self.plan.host_fraction * 100.0,
            self.host_threads,
            if self.device.is_some() { "device" } else { "host-sim device" }
        )
    }

    /// Route a call over `n` elements: one engine for small inputs and
    /// degenerate splits, otherwise a concurrent two-engine split. Every
    /// co-processing entry point (and the session's hybrid search)
    /// shares this rule, so device-only plans consistently reach the
    /// device engine.
    pub fn route(&self, n: usize) -> CoRoute {
        self.route_with(n, MIN_COSPLIT)
    }

    /// [`HybridEngine::route`] with an explicit co-split gate (the
    /// `Launch::prefer_parallel_threshold` override).
    pub fn route_with(&self, n: usize, min_split: usize) -> CoRoute {
        let split = self.plan.split_index(n);
        if n < min_split.max(2) || split == n {
            // Tiny inputs always take the host pool — cheaper than a
            // spawn, regardless of the plan.
            CoRoute::Host
        } else if split == 0 {
            CoRoute::Device
        } else {
            CoRoute::Split(split)
        }
    }
}

/// How a hybrid call routes (see [`HybridEngine::route`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CoRoute {
    /// Whole call on the host pool.
    Host,
    /// Whole call on the device engine.
    Device,
    /// Concurrent split: `[0, i)` host, `[i, n)` device.
    Split(usize),
}

impl std::fmt::Debug for HybridEngine {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.describe())
    }
}

fn join_flat<T>(res: std::thread::Result<AkResult<T>>, who: &str, op: &str) -> AkResult<T> {
    match res {
        Ok(inner) => inner,
        Err(_) => Err(AkError::panicked(who, op)),
    }
}

// ---- per-shard engines ------------------------------------------------------

/// Host-shard sort: the threaded chunk-sort + merge-path engine with the
/// launch's worker/gate knobs. `scratch` is the merge buffer (the
/// session's pooled buffer on the whole-host route, a shard-local one
/// inside a concurrent split).
fn host_shard_sort<K: SortKey>(
    eng: &HybridEngine,
    xs: &mut [K],
    l: &Launch,
    scratch: &mut Vec<K>,
) -> AkResult<()> {
    let t = l.tasks_for(eng.host_threads, xs.len());
    threaded_sort(
        xs,
        t,
        l.par_threshold_or(DEFAULT_PAR_THRESHOLD),
        l.par_threshold_or(merge_path::PAR_MERGE_MIN),
        scratch,
    );
    Ok(())
}

/// Device-shard sort: the artifact engine when one is attached (with
/// the launch's `block_size` granule), the documented single-thread
/// host stand-in otherwise — including for dtypes without an XLA
/// family (i128): the hybrid engine owns a host pool, so the shard
/// degrades like `device_shard_reduce` does instead of failing the
/// whole co-sort (the pure `Backend::Device` sort is the strict,
/// typed-error path — DESIGN.md §12).
fn device_shard_sort<K: DeviceKey>(eng: &HybridEngine, xs: &mut [K], l: &Launch) -> AkResult<()> {
    match &eng.device {
        Some(dev) if K::XLA => {
            dev.sort_blocked(xs, l.block_size).map_err(|e| AkError::device("co_sort", e))
        }
        _ => {
            xs.sort_unstable_by(|a, b| a.cmp_total(b));
            Ok(())
        }
    }
}

fn device_shard_reduce<K: Reducible>(
    eng: &HybridEngine,
    xs: &[K],
    kind: ReduceKind,
    l: &Launch,
) -> AkResult<K> {
    match &eng.device {
        Some(dev) if K::XLA => {
            if kind == ReduceKind::Add && xs.len() <= l.switch_below_or(0) {
                return dev.reduce_partials_add_shim(xs).map_err(|e| AkError::device("co_reduce", e));
            }
            dev.reduce(xs, kind.op_name(), K::identity(kind), |a, b| K::fold(kind, a, b))
                .map_err(|e| AkError::device("co_reduce", e))
        }
        // i128 or no device: the documented host stand-in.
        _ => Ok(host_reduce(xs, kind)),
    }
}

fn host_shard_reduce<K: Reducible>(
    eng: &HybridEngine,
    xs: &[K],
    kind: ReduceKind,
    l: &Launch,
) -> K {
    let t = l.tasks_for(eng.host_threads, xs.len());
    if t <= 1 || xs.len() < l.par_threshold_or(DEFAULT_PAR_THRESHOLD) {
        return host_reduce(xs, kind);
    }
    let partials =
        crate::backend::parallel_for_each_chunk(xs.len(), t, |r| host_reduce(&xs[r], kind));
    partials.into_iter().fold(K::identity(kind), |a, b| K::fold(kind, a, b))
}

// ---- co-processing entry points ---------------------------------------------

/// Hybrid co-sort — the flagship: split at the plan, sort both shards
/// concurrently (host thread pool ∥ device engine), then recombine with
/// the merge-path partitioned parallel merge on the host pool. Output
/// equals `sort_by(cmp_total)` for every dtype and split ratio (total
/// order; NaN-safe for floats).
///
/// ```
/// use accelkern::hybrid::{co_sort, HybridEngine, HybridPlan};
/// let eng = HybridEngine::new(HybridPlan::new(0.5), 2, None);
/// let mut v = vec![5i32, -3, 7, 0, 2, 9, -8, 4];
/// co_sort(&eng, &mut v).unwrap();
/// assert_eq!(v, vec![-8, -3, 0, 2, 4, 5, 7, 9]);
/// ```
pub fn co_sort<K: DeviceKey>(eng: &HybridEngine, xs: &mut [K]) -> AkResult<()> {
    co_sort_launch(eng, xs, &Launch::default())
}

/// [`co_sort`] with per-call [`Launch`] knobs (the session's hybrid
/// sort dispatch).
pub fn co_sort_launch<K: DeviceKey>(
    eng: &HybridEngine,
    xs: &mut [K],
    l: &Launch,
) -> AkResult<()> {
    let mut scratch: Vec<K> = Vec::new();
    co_sort_scratch(eng, xs, l, &mut scratch)
}

/// [`co_sort_launch`] with a caller-owned recombine scratch buffer —
/// how `Launch::reuse_scratch` reaches the hybrid path: the session
/// hands its pooled n-element buffer in here (the dominant allocation;
/// the concurrent host shard keeps a shard-local buffer, since it runs
/// while the pooled one is reserved for the recombine).
pub(crate) fn co_sort_scratch<K: DeviceKey>(
    eng: &HybridEngine,
    xs: &mut [K],
    l: &Launch,
    scratch: &mut Vec<K>,
) -> AkResult<()> {
    let split = match eng.route_with(xs.len(), l.par_threshold_or(MIN_COSPLIT)) {
        CoRoute::Host => return host_shard_sort(eng, xs, l, scratch),
        CoRoute::Device => return device_shard_sort(eng, xs, l),
        CoRoute::Split(split) => split,
    };
    let (host_half, dev_half) = xs.split_at_mut(split);
    let (host_res, dev_res) = std::thread::scope(|s| {
        let h = s.spawn(move || {
            let mut shard_scratch: Vec<K> = Vec::new();
            host_shard_sort(eng, host_half, l, &mut shard_scratch)
        });
        let d = s.spawn(move || device_shard_sort(eng, dev_half, l));
        (h.join(), d.join())
    });
    join_flat(host_res, "host", "co_sort")?;
    join_flat(dev_res, "device", "co_sort")?;
    // Recombine on the host pool: merge-path partitioned 2-way merge
    // (DESIGN.md §11) — each of the host threads produces one contiguous
    // segment of the merged output, then the copy-back runs on the same
    // pool, so no recombine sweep caps at one core's bandwidth.
    let t = l.tasks_for(eng.host_threads, xs.len());
    merge_path::merge_runs_in_place_with(
        xs,
        &[split],
        t,
        l.par_threshold_or(merge_path::PAR_MERGE_MIN),
        scratch,
    );
    Ok(())
}

/// Hybrid co-reduce: both engines reduce their shard concurrently, the
/// partials fold on the host. The `switch_below` launch knob is
/// forwarded to the device shard (paper §II-B's device-sync-masking
/// rule).
pub fn co_reduce<K: Reducible>(
    eng: &HybridEngine,
    xs: &[K],
    kind: ReduceKind,
    switch_below: usize,
) -> AkResult<K> {
    co_reduce_launch(eng, xs, kind, &Launch::new().switch_below(switch_below))
}

/// [`co_reduce`] with per-call [`Launch`] knobs.
pub fn co_reduce_launch<K: Reducible>(
    eng: &HybridEngine,
    xs: &[K],
    kind: ReduceKind,
    l: &Launch,
) -> AkResult<K> {
    let split = match eng.route_with(xs.len(), l.par_threshold_or(MIN_COSPLIT)) {
        CoRoute::Host => return Ok(host_shard_reduce(eng, xs, kind, l)),
        CoRoute::Device => return device_shard_reduce(eng, xs, kind, l),
        CoRoute::Split(split) => split,
    };
    let (host_half, dev_half) = xs.split_at(split);
    let (host_res, dev_res) = std::thread::scope(|s| {
        let h = s.spawn(move || Ok(host_shard_reduce(eng, host_half, kind, l)));
        let d = s.spawn(move || device_shard_reduce(eng, dev_half, kind, l));
        (h.join(), d.join())
    });
    let a = join_flat(host_res, "host", "co_reduce")?;
    let b = join_flat(dev_res, "device", "co_reduce")?;
    Ok(K::fold(kind, a, b))
}

/// Hybrid co-foreach: the host shard of the index space runs on the
/// thread pool while the device shard runs on the device engine's
/// `foreachindex` emulation (named-kernel semantics: sequential walk —
/// arbitrary closures cannot cross the AOT boundary, see
/// `algorithms::foreach`). Both shards execute concurrently.
pub fn co_foreachindex<F>(eng: &HybridEngine, len: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    co_foreachindex_launch(eng, len, &f, &Launch::default());
}

/// [`co_foreachindex`] with per-call [`Launch`] knobs.
pub fn co_foreachindex_launch<F>(eng: &HybridEngine, len: usize, f: &F, l: &Launch)
where
    F: Fn(usize) + Sync,
{
    let threads = l.tasks_for(eng.host_threads, len);
    // The foreach "device engine" is always a sequential walk (arbitrary
    // closures cannot cross the AOT boundary), so cap its shard at one
    // worker's share no matter how device-heavy the sort-calibrated plan
    // is — otherwise a device-heavy plan collapses the loop to
    // single-thread throughput.
    let split = eng.plan.split_index(len).max(len.saturating_sub(len / (threads + 1)));
    if len < l.par_threshold_or(MIN_COSPLIT).max(2) || split == len {
        // Whole call on the host pool — same sequential gate as a
        // Threaded session, so `prefer_parallel_threshold` forces the
        // sequential engine here too.
        if threads <= 1 || len < l.par_threshold_or(DEFAULT_PAR_THRESHOLD) {
            for i in 0..len {
                f(i);
            }
            return;
        }
        crate::backend::parallel_for_each_chunk(len, threads, |r| {
            for i in r {
                f(i);
            }
        });
        return;
    }
    std::thread::scope(|s| {
        s.spawn(move || {
            crate::backend::parallel_for_each_chunk(split, threads, |r| {
                for i in r {
                    f(i);
                }
            });
        });
        s.spawn(move || {
            for i in split..len {
                f(i);
            }
        });
    });
}

/// Mutating hybrid co-foreach over a slice: disjoint halves, host pool ∥
/// device-engine emulation, indices preserved.
pub fn co_foreach_mut<T: Send, F>(eng: &HybridEngine, xs: &mut [T], f: F)
where
    F: Fn(usize, &mut T) + Sync,
{
    co_foreach_mut_launch(eng, xs, &f, &Launch::default());
}

/// [`co_foreach_mut`] with per-call [`Launch`] knobs.
pub fn co_foreach_mut_launch<T: Send, F>(eng: &HybridEngine, xs: &mut [T], f: &F, l: &Launch)
where
    F: Fn(usize, &mut T) + Sync,
{
    let n = xs.len();
    let threads = l.tasks_for(eng.host_threads, n);
    // Same sequential-walk cap as `co_foreachindex`.
    let split = eng.plan.split_index(n).max(n.saturating_sub(n / (threads + 1)));
    if n < l.par_threshold_or(MIN_COSPLIT).max(2) || split == n {
        // Same sequential gate as `co_foreachindex_launch`.
        if threads <= 1 || n < l.par_threshold_or(DEFAULT_PAR_THRESHOLD) {
            for (i, x) in xs.iter_mut().enumerate() {
                f(i, x);
            }
            return;
        }
        let ranges = crate::backend::threaded::split_ranges(n, threads);
        crate::backend::parallel_chunks(xs, threads, |ci, chunk| {
            let base = ranges[ci].start;
            for (j, x) in chunk.iter_mut().enumerate() {
                f(base + j, x);
            }
        });
        return;
    }
    let (host_half, dev_half) = xs.split_at_mut(split);
    std::thread::scope(|s| {
        s.spawn(move || {
            let ranges = crate::backend::threaded::split_ranges(host_half.len(), threads);
            crate::backend::parallel_chunks(host_half, threads, |ci, chunk| {
                let base = ranges[ci].start;
                for (j, x) in chunk.iter_mut().enumerate() {
                    f(base + j, x);
                }
            });
        });
        s.spawn(move || {
            for (j, x) in dev_half.iter_mut().enumerate() {
                f(split + j, x);
            }
        });
    });
}

fn device_shard_any<K: DeviceKey>(
    eng: &HybridEngine,
    xs: &[K],
    threshold: K,
    _l: &Launch,
) -> AkResult<bool> {
    match &eng.device {
        Some(dev) if K::XLA && dev.registry().supports("any_gt", K::ELEM) => {
            dev.any_gt(xs, threshold).map_err(|e| AkError::device("co_any_gt", e))
        }
        _ => Ok(xs.iter().any(|&x| x > threshold)),
    }
}

fn device_shard_all<K: DeviceKey>(
    eng: &HybridEngine,
    xs: &[K],
    threshold: K,
    _l: &Launch,
) -> AkResult<bool> {
    match &eng.device {
        Some(dev) if K::XLA && dev.registry().supports("all_gt", K::ELEM) => {
            dev.all_gt(xs, threshold).map_err(|e| AkError::device("co_all_gt", e))
        }
        _ => Ok(xs.iter().all(|&x| x > threshold)),
    }
}

fn host_shard_any<K: DeviceKey>(eng: &HybridEngine, xs: &[K], threshold: K, l: &Launch) -> bool {
    host_any(
        xs,
        l.tasks_for(eng.host_threads, xs.len()),
        l.par_threshold_or(DEFAULT_PAR_THRESHOLD),
        |x: K| x > threshold,
    )
}

/// Hybrid `any(x > t)` for every sortable dtype: both engines scan
/// their shard concurrently with their own early exit; the results OR.
pub fn co_any_gt<K: DeviceKey>(eng: &HybridEngine, xs: &[K], threshold: K) -> AkResult<bool> {
    co_any_gt_launch(eng, xs, threshold, &Launch::default())
}

/// [`co_any_gt`] with per-call [`Launch`] knobs.
pub fn co_any_gt_launch<K: DeviceKey>(
    eng: &HybridEngine,
    xs: &[K],
    threshold: K,
    l: &Launch,
) -> AkResult<bool> {
    let split = match eng.route_with(xs.len(), l.par_threshold_or(MIN_COSPLIT)) {
        CoRoute::Host => return Ok(host_shard_any(eng, xs, threshold, l)),
        CoRoute::Device => return device_shard_any(eng, xs, threshold, l),
        CoRoute::Split(split) => split,
    };
    let (a, b) = xs.split_at(split);
    let (host_res, dev_res) = std::thread::scope(|s| {
        let h = s.spawn(move || Ok(host_shard_any(eng, a, threshold, l)));
        let d = s.spawn(move || device_shard_any(eng, b, threshold, l));
        (h.join(), d.join())
    });
    Ok(join_flat(host_res, "host", "co_any_gt")? || join_flat(dev_res, "device", "co_any_gt")?)
}

/// Hybrid `all(x > t)`: both engines scan concurrently; the results AND.
pub fn co_all_gt<K: DeviceKey>(eng: &HybridEngine, xs: &[K], threshold: K) -> AkResult<bool> {
    co_all_gt_launch(eng, xs, threshold, &Launch::default())
}

/// [`co_all_gt`] with per-call [`Launch`] knobs.
pub fn co_all_gt_launch<K: DeviceKey>(
    eng: &HybridEngine,
    xs: &[K],
    threshold: K,
    l: &Launch,
) -> AkResult<bool> {
    let split = match eng.route_with(xs.len(), l.par_threshold_or(MIN_COSPLIT)) {
        CoRoute::Host => {
            // Hunt for a counterexample of `x > t` (IEEE: NaN is one).
            let counter = host_any(
                xs,
                l.tasks_for(eng.host_threads, xs.len()),
                l.par_threshold_or(DEFAULT_PAR_THRESHOLD),
                |x: K| !matches!(x.partial_cmp(&threshold), Some(std::cmp::Ordering::Greater)),
            );
            return Ok(!counter);
        }
        CoRoute::Device => return device_shard_all(eng, xs, threshold, l),
        CoRoute::Split(split) => split,
    };
    let (a, b) = xs.split_at(split);
    let (host_res, dev_res) = std::thread::scope(|s| {
        let h = s.spawn(move || {
            let counter = host_any(
                a,
                l.tasks_for(eng.host_threads, a.len()),
                l.par_threshold_or(DEFAULT_PAR_THRESHOLD),
                |x: K| !matches!(x.partial_cmp(&threshold), Some(std::cmp::Ordering::Greater)),
            );
            Ok(!counter)
        });
        let d = s.spawn(move || device_shard_all(eng, b, threshold, l));
        (h.join(), d.join())
    });
    Ok(join_flat(host_res, "host", "co_all_gt")? && join_flat(dev_res, "device", "co_all_gt")?)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dtype::{is_sorted_total, SortKey};
    use crate::util::Prng;
    use crate::workload::{generate, Distribution, KeyGen};

    fn engine(frac: f64) -> HybridEngine {
        HybridEngine::new(HybridPlan::new(frac), 3, None)
    }

    fn check_cosort<K: KeyGen + PartialEq + DeviceKey>(seed: u64, n: usize) {
        for dist in [
            Distribution::Uniform,
            Distribution::Sorted,
            Distribution::Reverse,
            Distribution::DupHeavy,
        ] {
            let orig: Vec<K> = generate(&mut Prng::new(seed), dist, n);
            let mut want = orig.clone();
            want.sort_by(|a, b| a.cmp_total(b));
            for frac in [0.0, 0.3, 0.5, 0.9, 1.0] {
                let mut got = orig.clone();
                co_sort(&engine(frac), &mut got).unwrap();
                assert!(got == want, "{dist:?} frac={frac} n={n}");
            }
        }
    }

    #[test]
    fn cosort_matches_total_sort_all_dtypes() {
        check_cosort::<i16>(1, 20_000);
        check_cosort::<i32>(2, 20_000);
        check_cosort::<i64>(3, 20_000);
        check_cosort::<i128>(4, 20_000);
        check_cosort::<f32>(5, 20_000);
        check_cosort::<f64>(6, 20_000);
    }

    #[test]
    fn cosort_tiny_and_empty_inputs() {
        for n in [0usize, 1, 2, 5, 100] {
            check_cosort::<i32>(7, n);
        }
    }

    #[test]
    fn cosort_handles_float_specials() {
        let mut xs: Vec<f64> =
            generate(&mut Prng::new(8), Distribution::Uniform, MIN_COSPLIT * 2);
        xs[17] = f64::NAN;
        xs[1234] = f64::INFINITY;
        xs[8888] = f64::NEG_INFINITY;
        xs[9999] = -0.0;
        let mut want = xs.clone();
        want.sort_by(|a, b| a.cmp_total(b));
        let mut got = xs;
        co_sort(&engine(0.4), &mut got).unwrap();
        assert!(is_sorted_total(&got));
        let bits = |v: &[f64]| v.iter().map(|x| x.to_bits()).collect::<Vec<u64>>();
        assert_eq!(bits(&got), bits(&want));
    }

    #[test]
    fn cosort_launch_knobs_preserve_results() {
        let xs: Vec<i64> = generate(&mut Prng::new(12), Distribution::Uniform, MIN_COSPLIT * 3);
        let mut want = xs.clone();
        want.sort_unstable();
        for l in [
            Launch::new().max_tasks(1),
            Launch::new().min_elems_per_task(MIN_COSPLIT),
            Launch::new().prefer_parallel_threshold(64),
            Launch::new().prefer_parallel_threshold(usize::MAX),
        ] {
            let mut got = xs.clone();
            co_sort_launch(&engine(0.5), &mut got, &l).unwrap();
            assert_eq!(got, want, "{l:?}");
        }
    }

    #[test]
    fn coreduce_matches_host() {
        let xs: Vec<i64> = generate(&mut Prng::new(9), Distribution::Uniform, 30_000);
        let want: i64 = xs.iter().fold(0i64, |a, &b| a.wrapping_add(b));
        for frac in [0.0, 0.5, 1.0] {
            let got = co_reduce(&engine(frac), &xs, ReduceKind::Add, 0).unwrap();
            assert_eq!(got, want, "frac {frac}");
            let mn = co_reduce(&engine(frac), &xs, ReduceKind::Min, 0).unwrap();
            assert_eq!(mn, *xs.iter().min().unwrap());
        }
    }

    #[test]
    fn coforeach_visits_every_index_once() {
        use std::sync::atomic::{AtomicU32, Ordering};
        let n = MIN_COSPLIT + 1000;
        let hits: Vec<AtomicU32> = (0..n).map(|_| AtomicU32::new(0)).collect();
        co_foreachindex(&engine(0.6), n, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn coforeach_mut_copy_kernel() {
        let n = MIN_COSPLIT + 321;
        let src: Vec<u64> = (0..n as u64).collect();
        let mut dst = vec![0u64; n];
        co_foreach_mut(&engine(0.5), &mut dst, |i, d| *d = src[i]);
        assert_eq!(dst, src);
    }

    #[test]
    fn copredicates_or_and_across_shards() {
        let n = MIN_COSPLIT * 2;
        let mut xs = vec![0.0f32; n];
        // Hit only in the device shard at frac 0.5.
        xs[n - 7] = 5.0;
        let eng = engine(0.5);
        assert!(co_any_gt(&eng, &xs, 1.0).unwrap());
        assert!(!co_any_gt(&eng, &xs, 10.0).unwrap());
        assert!(co_all_gt(&eng, &xs, -1.0).unwrap());
        assert!(!co_all_gt(&eng, &xs, 0.5).unwrap());
        // Hit only in the host shard.
        let mut ys = vec![0.0f32; n];
        ys[3] = 5.0;
        assert!(co_any_gt(&eng, &ys, 1.0).unwrap());
    }

    #[test]
    fn copredicates_generic_dtypes() {
        let n = MIN_COSPLIT * 2;
        let mut xs = vec![0i64; n];
        xs[n - 3] = 9;
        let eng = engine(0.5);
        assert!(co_any_gt(&eng, &xs, 5i64).unwrap());
        assert!(!co_any_gt(&eng, &xs, 9i64).unwrap());
        assert!(co_all_gt(&eng, &xs, -1i64).unwrap());
        assert!(!co_all_gt(&eng, &xs, 0i64).unwrap());
    }

    #[test]
    fn engine_describe_mentions_plan() {
        let eng = engine(0.25);
        assert!(eng.describe().contains("25%"));
        assert!(eng.describe().contains("host-sim"));
    }

    #[test]
    fn route_rule_is_shared_and_consistent() {
        // Tiny inputs take the host pool regardless of the plan.
        assert_eq!(engine(0.0).route(100), CoRoute::Host);
        assert_eq!(engine(1.0).route(100), CoRoute::Host);
        // Degenerate fractions route the whole call to the owning engine.
        assert_eq!(engine(0.0).route(MIN_COSPLIT), CoRoute::Device);
        assert_eq!(engine(1.0).route(MIN_COSPLIT), CoRoute::Host);
        // Proper fractions split.
        assert_eq!(engine(0.5).route(MIN_COSPLIT * 2), CoRoute::Split(MIN_COSPLIT));
        // The launch gate moves the split point.
        assert_eq!(engine(0.5).route_with(1000, 500), CoRoute::Split(500));
        assert_eq!(engine(0.5).route_with(1000, 2000), CoRoute::Host);
    }
}
