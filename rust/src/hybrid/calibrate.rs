//! Calibration: measure the actual host:device throughput ratio on this
//! machine and turn it into a [`HybridPlan`].
//!
//! One measurement run produces a [`SortCalibration`]; plans for any
//! device model / cost ratio derive from it *without* re-measuring, so
//! the plan-shift invariants (faster device model ⇒ smaller host share,
//! higher cost ratio ⇒ larger host share) hold deterministically even
//! though the underlying timings are noisy.

use std::time::Instant;

use crate::backend::{DeviceKey, DeviceOps};
use crate::cluster::DeviceModel;
use crate::util::Prng;
use crate::workload::{generate, Distribution, KeyGen};

use super::plan::HybridPlan;

/// Outcome of one sort-throughput calibration run.
#[derive(Clone, Copy, Debug)]
pub struct SortCalibration {
    /// Elements in the measured shard.
    pub elems: usize,
    /// Host (threaded) engine throughput, elements per second.
    pub host_elems_per_sec: f64,
    /// Measured single-thread seconds for the same shard — the baseline
    /// the device model scales (`cluster/devmodel.rs`).
    pub single_thread_secs: f64,
    /// Real device throughput (elements/s) when a device engine with
    /// artifacts was measured; `None` means plans use the device model.
    pub device_elems_per_sec: Option<f64>,
}

impl SortCalibration {
    /// Device-engine throughput under `devmodel`: the real measurement if
    /// one exists, otherwise the single-thread baseline scaled by the
    /// model's `gpu_speedup`.
    pub fn device_throughput(&self, devmodel: &DeviceModel) -> f64 {
        if let Some(real) = self.device_elems_per_sec {
            return real;
        }
        let sim_secs = devmodel.compute_time(self.single_thread_secs, true).max(1e-12);
        self.elems as f64 / sim_secs
    }

    /// Device:host throughput ratio under `devmodel` (>1 means the device
    /// engine is faster).
    pub fn ratio(&self, devmodel: &DeviceModel) -> f64 {
        self.device_throughput(devmodel) / self.host_elems_per_sec.max(1e-12)
    }

    /// The model-projected calibrated split: plans as if the device shard
    /// ran on the simulated accelerator (`gpu_speedup`). Right for
    /// *simulated-time* reasoning and what-if projections; for splitting
    /// real work use [`SortCalibration::plan_measured`].
    /// `cost_ratio = 1.0` optimises makespan; the paper's `cost.rs` ×22
    /// optimises cost-normalised time.
    pub fn plan(&self, devmodel: &DeviceModel, cost_ratio: f64) -> HybridPlan {
        HybridPlan::cost_aware(
            self.host_elems_per_sec,
            self.device_throughput(devmodel),
            cost_ratio,
        )
    }

    /// Throughput of the engine that will *actually execute* the device
    /// shard: the measured artifact engine when one exists, else the
    /// single-host-thread stand-in (DESIGN.md §2) measured by this run.
    pub fn executing_device_throughput(&self) -> f64 {
        self.device_elems_per_sec
            .unwrap_or(self.elems as f64 / self.single_thread_secs.max(1e-12))
    }

    /// Wall-clock-optimal split for the engines as they will actually
    /// execute. This is the plan to drive real work with — under the
    /// no-artifact stand-in the model-projected [`SortCalibration::plan`]
    /// would hand ~all work to a single host thread and run far slower
    /// than host-only.
    pub fn plan_measured(&self, cost_ratio: f64) -> HybridPlan {
        HybridPlan::cost_aware(
            self.host_elems_per_sec,
            self.executing_device_throughput(),
            cost_ratio,
        )
    }
}

/// Measure sort throughput of the host engine (`host_threads` std
/// threads) and the device engine (real artifacts when `device` is given
/// and the dtype has an XLA family; the single-thread device-model
/// baseline otherwise) on an `n`-element uniform shard.
pub fn calibrate_sort<K: DeviceKey + KeyGen>(
    n: usize,
    host_threads: usize,
    device: Option<&DeviceOps>,
) -> anyhow::Result<SortCalibration> {
    let n = n.max(1024);
    let xs: Vec<K> = generate(&mut Prng::new(0xCA11B8), Distribution::Uniform, n);
    let host = crate::session::Session::threaded(host_threads.max(1));

    // Warm-up (thread spawn paths, branch predictors), then measure.
    let mut buf = xs.clone();
    host.sort(&mut buf, None)?;
    let mut buf = xs.clone();
    let t0 = Instant::now();
    host.sort(&mut buf, None)?;
    let host_secs = t0.elapsed().as_secs_f64().max(1e-9);

    // Single-thread baseline for the device model.
    let mut buf = xs.clone();
    let t0 = Instant::now();
    crate::session::Session::native().sort(&mut buf, None)?;
    let single_thread_secs = t0.elapsed().as_secs_f64().max(1e-9);

    let device_elems_per_sec = match device {
        Some(ops) if K::XLA => {
            // Warm up like the host engine: the first call pays one-time
            // lazy XLA compilation, which is a build cost, not throughput.
            let mut buf = xs.clone();
            ops.sort(&mut buf)?;
            let mut buf = xs.clone();
            let t0 = Instant::now();
            ops.sort(&mut buf)?;
            Some(n as f64 / t0.elapsed().as_secs_f64().max(1e-9))
        }
        _ => None,
    };

    Ok(SortCalibration {
        elems: n,
        host_elems_per_sec: n as f64 / host_secs,
        single_thread_secs,
        device_elems_per_sec,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn calibration_produces_sane_numbers() {
        let cal = calibrate_sort::<i32>(16 * 1024, 2, None).unwrap();
        assert_eq!(cal.elems, 16 * 1024);
        assert!(cal.host_elems_per_sec > 0.0);
        assert!(cal.single_thread_secs > 0.0);
        assert!(cal.device_elems_per_sec.is_none());
    }

    #[test]
    fn plans_shift_with_model_without_remeasuring() {
        // One measurement, two device models: the plan ordering is exact.
        let cal = calibrate_sort::<i64>(8 * 1024, 2, None).unwrap();
        let slow = cal.plan(&DeviceModel::new(1.0), 1.0);
        let fast = cal.plan(&DeviceModel::new(10_000.0), 1.0);
        assert!(
            fast.host_fraction < slow.host_fraction,
            "fast {} !< slow {}",
            fast.host_fraction,
            slow.host_fraction
        );
        // A 10000x device model should claim nearly everything.
        assert!(fast.host_fraction < 0.05, "host fraction {}", fast.host_fraction);

        // Cost normalisation moves work back onto the host.
        let dm = DeviceModel::new(200.0);
        let makespan = cal.plan(&dm, 1.0);
        let economic = cal.plan(&dm, 22.0);
        assert!(makespan.host_fraction < economic.host_fraction);

        // The ratio is consistent with the derived plan inputs.
        assert!(cal.ratio(&DeviceModel::new(10_000.0)) > cal.ratio(&DeviceModel::new(1.0)));
    }

    #[test]
    fn measured_plan_reflects_the_stand_in_not_the_model() {
        let cal = calibrate_sort::<i32>(8 * 1024, 4, None).unwrap();
        // Without artifacts the executing device engine is one host
        // thread, so the measured plan must keep a substantial host share
        // — never the ~0% the 200x model projection would pick.
        let measured = cal.plan_measured(1.0);
        assert!(
            measured.host_fraction >= 0.2,
            "measured host fraction {} too small for a 1-thread stand-in",
            measured.host_fraction
        );
        assert!(cal.executing_device_throughput() > 0.0);
        // The model projection is a different, device-heavier question.
        let projected = cal.plan(&DeviceModel::new(10_000.0), 1.0);
        assert!(projected.host_fraction < measured.host_fraction);
    }
}
