//! Hybrid CPU–GPU co-processing (DESIGN.md §10) — the paper's headline
//! composability result ("such as CPU-GPU co-sorting") inside one rank.
//!
//! Every other backend runs a call on exactly one engine. The hybrid
//! subsystem splits one call across **two engines at once**:
//!
//! 1. a [`plan::HybridPlan`] partitions the input using throughput
//!    estimates — measured by [`calibrate`], projected by
//!    [`crate::cluster::DeviceModel`], and optionally deflated by the
//!    paper's ×22 GPU:CPU cost ratio ([`crate::cost`]);
//! 2. [`cosort`] runs the host shard on a std-thread pool while the
//!    device shard runs on the AOT artifact engine, concurrently;
//! 3. results recombine: merge-path partitioned parallel merge
//!    ([`crate::baselines::merge_path`], DESIGN.md §11) for co-sort,
//!    operator fold for co-reduce, nothing for co-foreach.
//!
//! Wired through the stack as [`crate::backend::Backend::Hybrid`]
//! (algorithm suite), [`crate::cfg::Sorter::Hybrid`] /
//! `--backend hybrid` (CLI), and `mpisort::LocalSorter::Hybrid` (SIHSort
//! ranks co-sort their shards). `rust/benches/fig6_cosort.rs` measures
//! the weak-scaling behaviour; `examples/cosort.rs` demonstrates both
//! the single-shard and the distributed composition.

pub mod calibrate;
pub mod cosort;
pub mod plan;

pub use calibrate::{calibrate_sort, SortCalibration};
pub use cosort::{
    co_all_gt, co_all_gt_launch, co_any_gt, co_any_gt_launch, co_foreach_mut,
    co_foreach_mut_launch, co_foreachindex, co_foreachindex_launch, co_reduce, co_reduce_launch,
    co_sort, co_sort_launch, CoRoute, HybridEngine, MIN_COSPLIT,
};
pub use plan::HybridPlan;
