//! Split planning: how a hybrid call divides one input between the host
//! and device engines.
//!
//! The plan is a single host-side work fraction derived from throughput
//! estimates: measured engine throughputs (see [`super::calibrate`]), the
//! [`crate::cluster::DeviceModel`] projection when no real device exists,
//! and optionally the paper's ×22 GPU:CPU cost ratio
//! ([`crate::cost::hybrid_host_fraction`]) for economically-normalised
//! splits. Because the fraction is pure data, the same plan drives
//! co-sort, co-reduce and co-foreach identically, and tests can assert
//! how it shifts when the device model or cost ratio changes.

use crate::cluster::DeviceModel;
use crate::cost;

/// How a hybrid call splits one input: `[0, split)` goes to the host
/// engine, `[split, n)` to the device engine.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct HybridPlan {
    /// Fraction of elements the host engine owns, clamped to `[0, 1]`.
    pub host_fraction: f64,
}

impl HybridPlan {
    /// Plan with an explicit host fraction (clamped to `[0, 1]`).
    ///
    /// # Panics
    /// On a non-finite fraction.
    pub fn new(host_fraction: f64) -> HybridPlan {
        assert!(host_fraction.is_finite(), "host fraction must be finite, got {host_fraction}");
        HybridPlan { host_fraction: host_fraction.clamp(0.0, 1.0) }
    }

    /// Degenerate plan: everything on the host engine.
    pub fn host_only() -> HybridPlan {
        HybridPlan { host_fraction: 1.0 }
    }

    /// Degenerate plan: everything on the device engine.
    pub fn device_only() -> HybridPlan {
        HybridPlan { host_fraction: 0.0 }
    }

    /// Makespan-optimal split from measured engine throughputs: work
    /// proportional to speed, so both engines finish together.
    pub fn balanced(host_tput: f64, device_tput: f64) -> HybridPlan {
        HybridPlan::new(cost::hybrid_host_fraction(host_tput, device_tput, 1.0))
    }

    /// Cost-normalised split: the device throughput is deflated by the
    /// paper's GPU:CPU cost ratio before balancing (Fig 5 inverted into a
    /// planning rule — DESIGN.md §10).
    pub fn cost_aware(host_tput: f64, device_tput: f64, cost_ratio: f64) -> HybridPlan {
        HybridPlan::new(cost::hybrid_host_fraction(host_tput, device_tput, cost_ratio))
    }

    /// Split from the simulated device model: the device runs the same
    /// work `devmodel.gpu_speedup` times faster than the measured host
    /// throughput (`cluster/devmodel.rs`), deflated by `cost_ratio`.
    pub fn calibrated(devmodel: &DeviceModel, host_tput: f64, cost_ratio: f64) -> HybridPlan {
        HybridPlan::cost_aware(host_tput, devmodel.device_throughput(host_tput), cost_ratio)
    }

    /// The host shard length for an `n`-element input: `[0, split)` is
    /// host work, `[split, n)` device work.
    pub fn split_index(&self, n: usize) -> usize {
        ((n as f64 * self.host_fraction).round() as usize).min(n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fraction_clamped() {
        assert_eq!(HybridPlan::new(1.7).host_fraction, 1.0);
        assert_eq!(HybridPlan::new(-0.3).host_fraction, 0.0);
        assert_eq!(HybridPlan::new(0.25).host_fraction, 0.25);
    }

    #[test]
    #[should_panic]
    fn rejects_nan() {
        HybridPlan::new(f64::NAN);
    }

    #[test]
    fn split_edges() {
        assert_eq!(HybridPlan::host_only().split_index(100), 100);
        assert_eq!(HybridPlan::device_only().split_index(100), 0);
        assert_eq!(HybridPlan::new(0.5).split_index(100), 50);
        assert_eq!(HybridPlan::new(0.5).split_index(0), 0);
        assert_eq!(HybridPlan::new(0.5).split_index(1), 1); // rounds up
    }

    #[test]
    fn calibrated_shifts_with_devmodel_throughput() {
        // Acceptance invariant: a faster modelled device takes more work.
        let slow = HybridPlan::calibrated(&DeviceModel::new(2.0), 1e8, 1.0);
        let fast = HybridPlan::calibrated(&DeviceModel::new(200.0), 1e8, 1.0);
        assert!(
            fast.host_fraction < slow.host_fraction,
            "fast-device host fraction {} !< slow-device {}",
            fast.host_fraction,
            slow.host_fraction
        );
        // And the fractions are exactly the throughput-proportional ones.
        assert!((slow.host_fraction - 1.0 / 3.0).abs() < 1e-12);
        assert!((fast.host_fraction - 1.0 / 201.0).abs() < 1e-12);
    }

    #[test]
    fn calibrated_shifts_with_cost_ratio() {
        // Acceptance invariant: raising cost.rs's ratio moves work back to
        // the host (×22 on a 22x device = even split).
        let dm = DeviceModel::new(22.0);
        let makespan = HybridPlan::calibrated(&dm, 1e8, 1.0);
        let economic = HybridPlan::calibrated(&dm, 1e8, 22.0);
        assert!(makespan.host_fraction < economic.host_fraction);
        assert!((economic.host_fraction - 0.5).abs() < 1e-12);
    }

    #[test]
    fn balanced_is_cost_aware_at_unit_ratio() {
        assert_eq!(HybridPlan::balanced(3.0, 9.0), HybridPlan::cost_aware(3.0, 9.0, 1.0));
    }
}
