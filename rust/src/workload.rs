//! Workload generators for the paper's benchmarks.
//!
//! Figures 1–5 sort uniform random integers/floats of six dtypes; Table II
//! runs arithmetic kernels over uniform 3-D points. Beyond `Uniform` we
//! include the standard adversarial sorting distributions (sorted,
//! reverse, nearly-sorted, duplicate-heavy, Zipfian, Gaussian) used by the
//! ablation benches — real sorter rankings are distribution-sensitive and
//! the paper's "who wins where" claims should be checked off-uniform too.

use crate::dtype::SortKey;
use crate::util::Prng;

/// Input distribution for sorting workloads.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Distribution {
    /// Uniform over the full key range (the paper's benchmark input).
    Uniform,
    /// Already ascending.
    Sorted,
    /// Descending.
    Reverse,
    /// Ascending with ~1% random swaps.
    NearlySorted,
    /// Only `sqrt(n)` distinct values.
    DupHeavy,
    /// Zipf(s=1.1) ranks mapped over the key space.
    Zipf,
    /// Gaussian around the middle of the key space.
    Gaussian,
}

impl Distribution {
    pub const ALL: [Distribution; 7] = [
        Distribution::Uniform,
        Distribution::Sorted,
        Distribution::Reverse,
        Distribution::NearlySorted,
        Distribution::DupHeavy,
        Distribution::Zipf,
        Distribution::Gaussian,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Distribution::Uniform => "uniform",
            Distribution::Sorted => "sorted",
            Distribution::Reverse => "reverse",
            Distribution::NearlySorted => "nearly-sorted",
            Distribution::DupHeavy => "dup-heavy",
            Distribution::Zipf => "zipf",
            Distribution::Gaussian => "gaussian",
        }
    }

    pub fn parse(s: &str) -> Option<Distribution> {
        Self::ALL.into_iter().find(|d| d.name() == s)
    }
}

/// Per-dtype uniform draw (floats draw from a wide finite real range: raw
/// uniform bit images would be mostly NaN/Inf payloads).
pub trait KeyGen: SortKey {
    /// Draw one key uniformly from the type's benchmark range.
    fn uniform(rng: &mut Prng) -> Self;
}

impl KeyGen for i16 {
    fn uniform(rng: &mut Prng) -> Self {
        rng.next_u64() as i16
    }
}
impl KeyGen for i32 {
    fn uniform(rng: &mut Prng) -> Self {
        rng.next_u64() as i32
    }
}
impl KeyGen for i64 {
    fn uniform(rng: &mut Prng) -> Self {
        rng.next_u64() as i64
    }
}
impl KeyGen for i128 {
    fn uniform(rng: &mut Prng) -> Self {
        rng.next_i128()
    }
}
impl KeyGen for f32 {
    fn uniform(rng: &mut Prng) -> Self {
        (rng.uniform_f32() - 0.5) * 2.0e6
    }
}
impl KeyGen for f64 {
    fn uniform(rng: &mut Prng) -> Self {
        (rng.uniform_f64() - 0.5) * 2.0e12
    }
}

/// Generate `n` keys of type `K` from `dist`, deterministically from `rng`.
pub fn generate<K: KeyGen>(rng: &mut Prng, dist: Distribution, n: usize) -> Vec<K> {
    let mut xs: Vec<K> = match dist {
        Distribution::Uniform => (0..n).map(|_| K::uniform(rng)).collect(),
        Distribution::Sorted | Distribution::Reverse | Distribution::NearlySorted => {
            let mut v: Vec<K> = (0..n).map(|_| K::uniform(rng)).collect();
            v.sort_unstable_by(|a, b| a.cmp_total(b));
            v
        }
        Distribution::DupHeavy => {
            let k = (n as f64).sqrt().ceil() as usize;
            let pool: Vec<K> = (0..k.max(1)).map(|_| K::uniform(rng)).collect();
            (0..n).map(|_| pool[rng.below(pool.len() as u64) as usize]).collect()
        }
        Distribution::Zipf => {
            // Zipf(s=1.1) over a pool of distinct uniform keys via
            // inverse-CDF on a harmonic prefix table (<= 10k ranks).
            let ranks = n.clamp(1, 10_000);
            let mut cdf = Vec::with_capacity(ranks);
            let mut acc = 0.0f64;
            for r in 1..=ranks {
                acc += 1.0 / (r as f64).powf(1.1);
                cdf.push(acc);
            }
            let total = acc;
            let pool: Vec<K> = (0..ranks).map(|_| K::uniform(rng)).collect();
            (0..n)
                .map(|_| {
                    let u = rng.uniform_f64() * total;
                    let idx = cdf.partition_point(|&c| c < u).min(ranks - 1);
                    pool[idx]
                })
                .collect()
        }
        Distribution::Gaussian => {
            // Sort a uniform pool and pick indices ~ N(n/2, n/8): produces
            // a value distribution concentrated mid-range for every dtype
            // without assuming anything about the bit image.
            let mut pool: Vec<K> = (0..n.max(2)).map(|_| K::uniform(rng)).collect();
            pool.sort_unstable_by(|a, b| a.cmp_total(b));
            let m = pool.len() as f64;
            (0..n)
                .map(|_| {
                    let z = rng.normal_f64().clamp(-4.0, 4.0);
                    let idx = (m / 2.0 + z * m / 8.0).clamp(0.0, m - 1.0) as usize;
                    pool[idx]
                })
                .collect()
        }
    };
    match dist {
        Distribution::Reverse => xs.reverse(),
        Distribution::NearlySorted => {
            let swaps = (n / 100).max(1);
            for _ in 0..swaps {
                if n >= 2 {
                    let i = rng.below(n as u64) as usize;
                    let j = rng.below(n as u64) as usize;
                    xs.swap(i, j);
                }
            }
        }
        _ => {}
    }
    xs
}

/// 3-D point cloud for the Table II arithmetic kernels: coordinates laid
/// out as `[x0..xn, y0..yn, z0..zn]` ("stored inline", matching the
/// paper's layout in both Julia and C). Each coordinate is in
/// [-0.5, 0.5), so r < sqrt(0.75) ≈ 0.87 and the RBF denominator `1 - r`
/// stays away from 0.
pub fn points_f32(rng: &mut Prng, n: usize) -> Vec<f32> {
    (0..3 * n).map(|_| rng.uniform_f32() - 0.5).collect()
}

/// f64 variant of [`points_f32`].
pub fn points_f64(rng: &mut Prng, n: usize) -> Vec<f64> {
    (0..3 * n).map(|_| rng.uniform_f64() - 0.5).collect()
}

/// Atom positions for the LJG kernel: coords uniform in [0, box_len).
pub fn positions_f32(rng: &mut Prng, n: usize, box_len: f32) -> Vec<f32> {
    (0..3 * n).map(|_| rng.uniform_f32() * box_len).collect()
}

/// f64 variant of [`positions_f32`].
pub fn positions_f64(rng: &mut Prng, n: usize, box_len: f64) -> Vec<f64> {
    (0..3 * n).map(|_| rng.uniform_f64() * box_len).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dtype::is_sorted_total;

    #[test]
    fn deterministic_per_seed() {
        let a: Vec<i32> = generate(&mut Prng::new(1), Distribution::Uniform, 100);
        let b: Vec<i32> = generate(&mut Prng::new(1), Distribution::Uniform, 100);
        assert_eq!(a, b);
    }

    #[test]
    fn sorted_is_sorted() {
        let xs: Vec<i64> = generate(&mut Prng::new(2), Distribution::Sorted, 500);
        assert!(is_sorted_total(&xs));
    }

    #[test]
    fn reverse_is_descending() {
        let xs: Vec<i32> = generate(&mut Prng::new(3), Distribution::Reverse, 500);
        let mut asc = xs.clone();
        asc.reverse();
        assert!(is_sorted_total(&asc));
    }

    #[test]
    fn dup_heavy_has_few_distinct() {
        let xs: Vec<i32> = generate(&mut Prng::new(4), Distribution::DupHeavy, 10_000);
        let mut d = xs.clone();
        d.sort_unstable();
        d.dedup();
        assert!(d.len() <= 110, "distinct = {}", d.len());
    }

    #[test]
    fn zipf_is_skewed() {
        let xs: Vec<i32> = generate(&mut Prng::new(5), Distribution::Zipf, 10_000);
        let mut counts = std::collections::HashMap::new();
        for x in &xs {
            *counts.entry(*x).or_insert(0usize) += 1;
        }
        let max = counts.values().max().unwrap();
        assert!(*max > 10_000 / counts.len() * 5, "top count {max} of {} distinct", counts.len());
    }

    #[test]
    fn floats_are_finite() {
        let xs: Vec<f64> = generate(&mut Prng::new(6), Distribution::Uniform, 1000);
        assert!(xs.iter().all(|x| x.is_finite()));
        let ys: Vec<f32> = generate(&mut Prng::new(7), Distribution::Gaussian, 1000);
        assert!(ys.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn all_dists_all_dtypes_smoke() {
        for d in Distribution::ALL {
            let _: Vec<i16> = generate(&mut Prng::new(8), d, 64);
            let _: Vec<i128> = generate(&mut Prng::new(8), d, 64);
            let _: Vec<f32> = generate(&mut Prng::new(8), d, 64);
        }
    }

    #[test]
    fn points_radius_bounded() {
        let pts = points_f32(&mut Prng::new(9), 1000);
        for i in 0..1000 {
            let (x, y, z) = (pts[i], pts[1000 + i], pts[2000 + i]);
            let r = (x * x + y * y + z * z).sqrt();
            assert!(r < 0.87, "r = {r}");
        }
    }

    #[test]
    fn distribution_parse() {
        for d in Distribution::ALL {
            assert_eq!(Distribution::parse(d.name()), Some(d));
        }
        assert_eq!(Distribution::parse("nope"), None);
    }

    #[test]
    fn gaussian_concentrated() {
        let xs: Vec<i32> = generate(&mut Prng::new(10), Distribution::Gaussian, 4000);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        let lo = sorted[sorted.len() / 4];
        let hi = sorted[3 * sorted.len() / 4];
        let span = (sorted[sorted.len() - 1] as i64 - sorted[0] as i64).unsigned_abs();
        let mid_span = (hi as i64 - lo as i64).unsigned_abs();
        assert!(mid_span < span / 3, "mid {mid_span} vs full {span}");
    }
}
