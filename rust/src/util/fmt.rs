//! Human-readable formatting for benchmark tables.

/// Format a byte count: `1.5 KB`, `2.0 GB`, ... (decimal units, matching
/// the paper's GB/s throughput convention).
pub fn fmt_bytes(bytes: f64) -> String {
    const UNITS: [&str; 6] = ["B", "KB", "MB", "GB", "TB", "PB"];
    let mut v = bytes;
    let mut u = 0;
    while v.abs() >= 1000.0 && u < UNITS.len() - 1 {
        v /= 1000.0;
        u += 1;
    }
    if u == 0 {
        format!("{v:.0} {}", UNITS[u])
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

/// Format seconds with an adaptive unit: `12.3 µs`, `4.56 ms`, `1.23 s`.
pub fn fmt_duration(secs: f64) -> String {
    let a = secs.abs();
    if a == 0.0 {
        "0 s".to_string()
    } else if a < 1e-6 {
        format!("{:.1} ns", secs * 1e9)
    } else if a < 1e-3 {
        format!("{:.2} µs", secs * 1e6)
    } else if a < 1.0 {
        format!("{:.2} ms", secs * 1e3)
    } else {
        format!("{secs:.3} s")
    }
}

/// Format a throughput in GB/s (the paper's headline unit).
pub fn fmt_throughput(bytes_per_sec: f64) -> String {
    format!("{:.3} GB/s", bytes_per_sec / 1e9)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes() {
        assert_eq!(fmt_bytes(512.0), "512 B");
        assert_eq!(fmt_bytes(1500.0), "1.50 KB");
        assert_eq!(fmt_bytes(2.0e9), "2.00 GB");
    }

    #[test]
    fn durations() {
        assert_eq!(fmt_duration(0.0), "0 s");
        assert_eq!(fmt_duration(2.5e-5), "25.00 µs");
        assert_eq!(fmt_duration(0.0042), "4.20 ms");
        assert_eq!(fmt_duration(1.5), "1.500 s");
    }

    #[test]
    fn throughput() {
        assert_eq!(fmt_throughput(855e9), "855.000 GB/s");
    }
}
