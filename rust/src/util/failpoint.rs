//! Deterministic fault-injection harness for the crash/resume suite
//! (DESIGN.md §15).
//!
//! A *fail point* is a named site in the streaming / cluster-sort code
//! (`failpoint::check("ext.merge.mid")?`) that is a no-op in normal
//! operation. A test (or the `AKBENCH_FAILPOINT` env hook, parsed once
//! at `akbench` start-up) can *arm* one named point so that its
//! `(skip + 1)`-th execution aborts — either by returning a
//! [`FailpointAbort`] error that unwinds through the normal `?` error
//! path, or by panicking to simulate abrupt process death mid-frame.
//!
//! Determinism model:
//! * hits are counted **per thread**, so in the simulated collective
//!   every rank thread trips at its *own* `(skip + 1)`-th visit of the
//!   armed site. Crucially this means an armed point fires on *every*
//!   rank — the in-process fabric's barriers would otherwise hang the
//!   survivors of a single-rank death (a `std::sync::Barrier` never
//!   disconnects). All ranks dying at the same named site *is* the
//!   simulated whole-process kill.
//! * per-thread counters are keyed by an arming *epoch*, reset whenever
//!   a new guard arms, so skip counts never leak between tests.
//! * arming takes a process-wide exclusive lock ([`FailpointGuard`]),
//!   serialising fault tests within one test binary; the guard disarms
//!   on drop (including unwinds), so a tripped panic cannot poison a
//!   later test.

use std::cell::RefCell;
use std::collections::HashMap;
use std::fmt;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Mutex, MutexGuard};

/// How an armed fail point aborts when it trips.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FailMode {
    /// Return a [`FailpointAbort`] error through the normal `?` path.
    Error,
    /// Panic — simulates abrupt process death with no error-path
    /// cleanup beyond `Drop` impls (the crash model the manifest's
    /// atomicity argument is written against).
    Panic,
}

/// The error an armed fail point injects in [`FailMode::Error`].
#[derive(Debug)]
pub struct FailpointAbort {
    /// Name of the tripped fail point.
    pub name: String,
    /// Per-thread hit count at the trip (== armed `skip + 1`).
    pub hits: u64,
}

impl fmt::Display for FailpointAbort {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "failpoint '{}' tripped (hit {})", self.name, self.hits)
    }
}

impl std::error::Error for FailpointAbort {}

#[derive(Clone)]
struct Armed {
    name: &'static str,
    skip: u64,
    mode: FailMode,
    epoch: u64,
}

static ANY_ARMED: AtomicBool = AtomicBool::new(false);
static ARMED: Mutex<Option<Armed>> = Mutex::new(None);
/// Serialises arming across tests in one binary (fault tests cannot
/// overlap — the armed site is process-global state).
static ARM_LOCK: Mutex<()> = Mutex::new(());
static EPOCH: Mutex<u64> = Mutex::new(0);

thread_local! {
    /// (arming epoch, per-site hit counts). Reset when the epoch moves.
    static HITS: RefCell<(u64, HashMap<&'static str, u64>)> =
        RefCell::new((0, HashMap::new()));
}

fn unpoisoned<T>(m: &'static Mutex<T>) -> MutexGuard<'static, T> {
    // A tripped Panic-mode fail point may unwind while holding nothing
    // of ours, but the *test* thread panicking elsewhere can poison
    // these locks; the protected state stays valid either way.
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// Exclusive arming handle. Dropping it (normally or during an unwind)
/// disarms the fail point and releases the process-wide fault lock.
pub struct FailpointGuard {
    _lock: MutexGuard<'static, ()>,
}

impl FailpointGuard {
    /// Disarm while keeping the process-wide fault lock held: the
    /// holder's resumed runs execute unarmed, and no other test can arm
    /// a site that those runs might traverse in the meantime.
    pub fn disarm(&self) {
        *unpoisoned(&ARMED) = None;
        ANY_ARMED.store(false, Ordering::SeqCst);
    }

    /// Swap the armed site without releasing the fault lock — lets one
    /// test chain crash → resume → crash again (the double-resume case)
    /// with no window in which another test could arm.
    pub fn rearm(&self, name: &'static str, skip: u64, mode: FailMode) {
        let epoch = {
            let mut e = unpoisoned(&EPOCH);
            *e += 1;
            *e
        };
        *unpoisoned(&ARMED) = Some(Armed { name, skip, mode, epoch });
        ANY_ARMED.store(true, Ordering::SeqCst);
    }
}

impl Drop for FailpointGuard {
    fn drop(&mut self) {
        *unpoisoned(&ARMED) = None;
        ANY_ARMED.store(false, Ordering::SeqCst);
    }
}

/// Arm `name` so each thread's `(skip + 1)`-th [`check`] of that site
/// aborts with `mode`. Holds the process-wide fault lock until the
/// returned guard drops.
pub fn arm(name: &'static str, skip: u64, mode: FailMode) -> FailpointGuard {
    let lock = unpoisoned(&ARM_LOCK);
    let epoch = {
        let mut e = unpoisoned(&EPOCH);
        *e += 1;
        *e
    };
    *unpoisoned(&ARMED) = Some(Armed { name, skip, mode, epoch });
    ANY_ARMED.store(true, Ordering::SeqCst);
    FailpointGuard { _lock: lock }
}

/// Parse the `AKBENCH_FAILPOINT` env hook — `name[:skip[:panic]]` —
/// and arm it for the process lifetime. Returns `None` when unset.
/// `main` holds the guard so CI can kill a real `akbench` run at a
/// named site (`AKBENCH_FAILPOINT=ext.merge.mid akbench bench-stream`).
pub fn arm_env() -> Option<FailpointGuard> {
    let spec = std::env::var("AKBENCH_FAILPOINT").ok()?;
    if spec.is_empty() {
        return None;
    }
    let mut parts = spec.splitn(3, ':');
    let name = parts.next().unwrap_or_default().to_string();
    let skip: u64 = parts.next().and_then(|s| s.parse().ok()).unwrap_or(0);
    let mode =
        if parts.next() == Some("panic") { FailMode::Panic } else { FailMode::Error };
    // The name must outlive the guard; env arming happens once per
    // process, so leaking the string is the static lifetime we need.
    let name: &'static str = Box::leak(name.into_boxed_str());
    Some(arm(name, skip, mode))
}

/// The fail-point site: a no-op unless `name` is armed, in which case
/// the calling thread's `(skip + 1)`-th visit aborts with the armed
/// [`FailMode`].
pub fn check(name: &'static str) -> anyhow::Result<()> {
    if !ANY_ARMED.load(Ordering::SeqCst) {
        return Ok(());
    }
    let armed = match unpoisoned(&ARMED).clone() {
        Some(a) if a.name == name => a,
        _ => return Ok(()),
    };
    let hits = HITS.with(|h| {
        let mut h = h.borrow_mut();
        if h.0 != armed.epoch {
            *h = (armed.epoch, HashMap::new());
        }
        let c = h.1.entry(name).or_insert(0);
        *c += 1;
        *c
    });
    if hits <= armed.skip {
        return Ok(());
    }
    match armed.mode {
        FailMode::Error => Err(FailpointAbort { name: name.to_string(), hits }.into()),
        FailMode::Panic => panic!("failpoint '{name}' tripped (hit {hits})"),
    }
}

/// True when `err`'s chain bottoms out in a [`FailpointAbort`] — how
/// tests distinguish an injected crash from a genuine failure.
pub fn is_abort(err: &anyhow::Error) -> bool {
    err.chain().any(|c| c.is::<FailpointAbort>())
}

/// Which fault-injection suite drives a registered site (see [`SITES`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SiteSuite {
    /// Killed and resumed by the `tests/crash_resume.rs` kill matrix.
    CrashResume,
    /// Exercised by `tests/fault_recovery.rs` through `FaultPlan` rules
    /// at the fabric op boundaries, not by the crash/resume matrix.
    FaultRecovery,
}

/// One registered fail-point site. [`SITES`] is the central registry:
/// `aklint` checks every `failpoint::check("name")` literal in the tree
/// against it (unknown literal, stale entry, or a [`CrashResume`] site
/// missing from the `tests/crash_resume.rs` kill matrix are findings),
/// and `aklint --fix-design` generates the DESIGN.md §15 site table
/// from it.
///
/// [`CrashResume`]: SiteSuite::CrashResume
#[derive(Clone, Copy, Debug)]
pub struct Site {
    /// The literal name passed to [`check`].
    pub name: &'static str,
    /// Repo-relative path of the module holding the `check` call.
    pub module: &'static str,
    /// Which fault suite kills/exercises the site.
    pub suite: SiteSuite,
    /// What the site marks (one line; lands in the DESIGN.md table).
    pub doc: &'static str,
}

/// The central fail-point site registry (DESIGN.md §15). Every
/// `failpoint::check("name")` literal in `rust/src` must appear here
/// exactly once — `make lint` enforces it.
pub const SITES: &[Site] = &[
    Site {
        name: "ext.run",
        module: "rust/src/stream/external_sort.rs",
        suite: SiteSuite::CrashResume,
        doc: "before a generation run is parked in the spill store",
    },
    Site {
        name: "ext.run.recorded",
        module: "rust/src/stream/external_sort.rs",
        suite: SiteSuite::CrashResume,
        doc: "after a generation run is recorded in the manifest",
    },
    Site {
        name: "ext.gen-done",
        module: "rust/src/stream/external_sort.rs",
        suite: SiteSuite::CrashResume,
        doc: "after the gen_done progress mark commits",
    },
    Site {
        name: "ext.merge.group",
        module: "rust/src/stream/external_sort.rs",
        suite: SiteSuite::CrashResume,
        doc: "after a merge group's output run commits",
    },
    Site {
        name: "ext.merge.mid",
        module: "rust/src/stream/external_sort.rs",
        suite: SiteSuite::CrashResume,
        doc: "inside a merge group, between output chunks",
    },
    Site {
        name: "ext.merge.retired",
        module: "rust/src/stream/external_sort.rs",
        suite: SiteSuite::CrashResume,
        doc: "after a merge group's input runs are retired",
    },
    Site {
        name: "ext.merge.pass",
        module: "rust/src/stream/external_sort.rs",
        suite: SiteSuite::CrashResume,
        doc: "after a full intermediate merge pass commits",
    },
    Site {
        name: "ext.final",
        module: "rust/src/stream/external_sort.rs",
        suite: SiteSuite::CrashResume,
        doc: "before the final streaming merge starts writing",
    },
    Site {
        name: "ext.final.mid",
        module: "rust/src/stream/external_sort.rs",
        suite: SiteSuite::CrashResume,
        doc: "inside the final merge, between output chunks",
    },
    Site {
        name: "manifest.rename",
        module: "rust/src/stream/manifest.rs",
        suite: SiteSuite::CrashResume,
        doc: "between the manifest temp-file write and its rename",
    },
    Site {
        name: "sih.park",
        module: "rust/src/mpisort/sihsort.rs",
        suite: SiteSuite::CrashResume,
        doc: "before the phase-1 parked shard run commits",
    },
    Site {
        name: "sih.parked",
        module: "rust/src/mpisort/sihsort.rs",
        suite: SiteSuite::CrashResume,
        doc: "after the parked shard phase mark commits",
    },
    Site {
        name: "sih.splitters",
        module: "rust/src/mpisort/sihsort.rs",
        suite: SiteSuite::CrashResume,
        doc: "before the refined splitter images commit",
    },
    Site {
        name: "sih.splitters.recorded",
        module: "rust/src/mpisort/sihsort.rs",
        suite: SiteSuite::CrashResume,
        doc: "after the splitter phase mark commits",
    },
    Site {
        name: "sih.exchange.sent",
        module: "rust/src/mpisort/exchange.rs",
        suite: SiteSuite::CrashResume,
        doc: "after a rank's sub-buckets are fully sent",
    },
    Site {
        name: "sih.exchange",
        module: "rust/src/mpisort/sihsort.rs",
        suite: SiteSuite::CrashResume,
        doc: "before the received exchange runs commit",
    },
    Site {
        name: "sih.exchange.recorded",
        module: "rust/src/mpisort/sihsort.rs",
        suite: SiteSuite::CrashResume,
        doc: "after the exchange phase mark commits",
    },
    Site {
        name: "sih.final",
        module: "rust/src/mpisort/sihsort.rs",
        suite: SiteSuite::CrashResume,
        doc: "before the phase-6 output run commits",
    },
    Site {
        name: "sih.final.mid",
        module: "rust/src/mpisort/sihsort.rs",
        suite: SiteSuite::CrashResume,
        doc: "inside the phase-6 k-way merge, between output chunks",
    },
    Site {
        name: "sih.done",
        module: "rust/src/mpisort/sihsort.rs",
        suite: SiteSuite::CrashResume,
        doc: "after the rank's completion mark commits",
    },
    Site {
        name: "driver.verify",
        module: "rust/src/coordinator/driver.rs",
        suite: SiteSuite::CrashResume,
        doc: "after every rank commits, before the driver verifies",
    },
    Site {
        name: "comm.send",
        module: "rust/src/comm/fabric.rs",
        suite: SiteSuite::FaultRecovery,
        doc: "fabric send op boundary (composes with FaultPlan rules)",
    },
    Site {
        name: "comm.recv",
        module: "rust/src/comm/fabric.rs",
        suite: SiteSuite::FaultRecovery,
        doc: "fabric recv op boundary (composes with FaultPlan rules)",
    },
];

/// Look `name` up in the central site registry ([`SITES`]).
pub fn site(name: &str) -> Option<&'static Site> {
    SITES.iter().find(|s| s.name == name)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unarmed_check_is_free() {
        check("never.armed").unwrap();
    }

    #[test]
    fn trips_after_skip_per_thread() {
        let _g = arm("fp.test.skip", 2, FailMode::Error);
        check("fp.test.skip").unwrap();
        check("fp.test.skip").unwrap();
        let err = check("fp.test.skip").unwrap_err();
        assert!(is_abort(&err), "{err}");
        let abort = err.downcast_ref::<FailpointAbort>().unwrap();
        assert_eq!(abort.hits, 3);
        // Other sites stay silent while a different one is armed.
        check("fp.test.other").unwrap();
        // A fresh thread counts its own hits from zero.
        std::thread::spawn(|| {
            check("fp.test.skip").unwrap();
            check("fp.test.skip").unwrap();
            assert!(check("fp.test.skip").is_err());
        })
        .join()
        .unwrap();
    }

    #[test]
    fn guard_drop_disarms_and_epoch_resets_counts() {
        {
            let _g = arm("fp.test.epoch", 0, FailMode::Error);
            assert!(check("fp.test.epoch").is_err());
        }
        check("fp.test.epoch").unwrap();
        // Re-arming starts a new epoch: the main thread's stale count
        // from the previous arming must not pre-trip the new one.
        let _g = arm("fp.test.epoch", 1, FailMode::Error);
        check("fp.test.epoch").unwrap();
        assert!(check("fp.test.epoch").is_err());
    }

    #[test]
    fn disarm_and_rearm_keep_the_lock() {
        let g = arm("fp.test.swap", 0, FailMode::Error);
        assert!(check("fp.test.swap").is_err());
        g.disarm();
        check("fp.test.swap").unwrap();
        // Rearming opens a fresh epoch: counts restart even on the same
        // thread and the new skip applies.
        g.rearm("fp.test.swap", 1, FailMode::Error);
        check("fp.test.swap").unwrap();
        assert!(check("fp.test.swap").is_err());
    }

    #[test]
    fn registry_names_are_unique_and_resolvable() {
        let mut seen = std::collections::HashSet::new();
        for s in SITES {
            assert!(seen.insert(s.name), "duplicate registry entry: {}", s.name);
            assert!(!s.doc.is_empty() && !s.module.is_empty(), "{}: empty metadata", s.name);
            assert_eq!(site(s.name).map(|r| r.name), Some(s.name));
        }
        assert!(site("no.such.site").is_none());
    }

    #[test]
    fn panic_mode_panics() {
        let _g = arm("fp.test.panic", 0, FailMode::Panic);
        let r = std::panic::catch_unwind(|| {
            let _ = check("fp.test.panic");
        });
        assert!(r.is_err());
    }
}
