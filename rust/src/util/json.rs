//! Minimal JSON parser for the artifact manifest (serde is unavailable
//! offline — DESIGN.md §9).
//!
//! Supports the full JSON grammar needed by `artifacts/manifest.json`:
//! objects, arrays, strings (with escapes), numbers, booleans, null.
//! Not performance-critical: parsed once at runtime start-up.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().filter(|n| *n >= 0.0 && n.fract() == 0.0).map(|n| n as usize)
    }

    /// `obj["key"]` convenience; returns Null for missing keys/non-objects.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        self.as_obj().and_then(|m| m.get(key)).unwrap_or(&NULL)
    }
}

/// Parse error with byte offset.
#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub offset: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "JSON error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), offset: self.i }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // BMP only (manifest never contains surrogates).
                            s.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Copy a UTF-8 run verbatim.
                    let start = self.i;
                    while let Some(c) = self.peek() {
                        if c == b'"' || c == b'\\' {
                            break;
                        }
                        self.i += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("invalid UTF-8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-')) {
            self.i += 1;
        }
        let txt = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        txt.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| JsonError { msg: format!("bad number '{txt}'"), offset: start })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse(r#""a\nb""#).unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": false}"#).unwrap();
        assert_eq!(j.get("a").as_arr().unwrap().len(), 3);
        assert_eq!(j.get("a").as_arr().unwrap()[2].get("b").as_str(), Some("x"));
        assert_eq!(j.get("c"), &Json::Bool(false));
        assert_eq!(j.get("missing"), &Json::Null);
    }

    #[test]
    fn manifest_shape() {
        let j = Json::parse(
            r#"{"version": 1, "artifacts": [{"name": "sort_i32_n10", "n": 1024,
                "inputs": [{"shape": [1024], "dtype": "i32"}]}]}"#,
        )
        .unwrap();
        let arts = j.get("artifacts").as_arr().unwrap();
        assert_eq!(arts[0].get("name").as_str(), Some("sort_i32_n10"));
        assert_eq!(arts[0].get("n").as_usize(), Some(1024));
        assert_eq!(
            arts[0].get("inputs").as_arr().unwrap()[0].get("shape").as_arr().unwrap()[0].as_usize(),
            Some(1024)
        );
    }

    #[test]
    fn errors() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("\"abc").is_err());
        assert!(Json::parse("12 34").is_err());
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(Json::parse(r#""é""#).unwrap(), Json::Str("é".into()));
    }

    #[test]
    fn whitespace_tolerant() {
        let j = Json::parse(" {\n\t\"k\" :\r [ 1 , 2 ] } ").unwrap();
        assert_eq!(j.get("k").as_arr().unwrap().len(), 2);
    }
}
