//! Sample statistics for the bench harness and metrics tables.

/// Summary statistics over a set of measurements (seconds, bytes, ...).
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub median: f64,
    pub p05: f64,
    pub p95: f64,
}

impl Summary {
    /// Compute a summary; `samples` need not be sorted. Empty input yields
    /// an all-zero summary (n = 0).
    pub fn of(samples: &[f64]) -> Self {
        if samples.is_empty() {
            return Self { n: 0, mean: 0.0, std: 0.0, min: 0.0, max: 0.0, median: 0.0, p05: 0.0, p95: 0.0 };
        }
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.total_cmp(b));
        Self {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            median: percentile_sorted(&sorted, 0.5),
            p05: percentile_sorted(&sorted, 0.05),
            p95: percentile_sorted(&sorted, 0.95),
        }
    }

    /// Relative standard deviation (coefficient of variation).
    pub fn rsd(&self) -> f64 {
        if self.mean == 0.0 { 0.0 } else { self.std / self.mean }
    }
}

/// Linear-interpolated percentile of an ascending-sorted slice; q in [0,1].
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let pos = q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        sorted[lo] + (sorted[hi] - sorted[lo]) * (pos - lo as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert!((s.std - (2.5f64).sqrt()).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.median, 3.0);
    }

    #[test]
    fn summary_single() {
        let s = Summary::of(&[7.5]);
        assert_eq!(s.n, 1);
        assert_eq!(s.mean, 7.5);
        assert_eq!(s.std, 0.0);
        assert_eq!(s.median, 7.5);
    }

    #[test]
    fn summary_empty() {
        let s = Summary::of(&[]);
        assert_eq!(s.n, 0);
        assert_eq!(s.mean, 0.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0];
        assert_eq!(percentile_sorted(&xs, 0.5), 5.0);
        assert_eq!(percentile_sorted(&xs, 0.0), 0.0);
        assert_eq!(percentile_sorted(&xs, 1.0), 10.0);
    }

    #[test]
    fn unsorted_input_ok() {
        let s = Summary::of(&[5.0, 1.0, 3.0]);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.median, 3.0);
    }
}
