//! Deterministic PRNG: splitmix64 seeding + xoshiro256** core.
//!
//! Used by the workload generators, the property-test framework and the
//! sampling stages of SIHSort. Deterministic across platforms so every
//! benchmark row and failing property case is reproducible from a seed.

/// xoshiro256** generator (Blackman & Vigna), seeded via splitmix64.
#[derive(Clone, Debug)]
pub struct Prng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Prng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Self { s }
    }

    /// Derive an independent stream (e.g. one per MPI rank).
    pub fn fork(&mut self, stream: u64) -> Self {
        Self::new(self.next_u64() ^ stream.wrapping_mul(0xA076_1D64_78BD_642F))
    }

    /// Next raw 64 bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, bound)` without modulo bias (Lemire's method).
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        if bound == 0 {
            return 0;
        }
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(bound as u128);
        let mut l = m as u64;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(bound as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform in the inclusive range `[lo, hi]`.
    #[inline]
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        let span = (hi as i128 - lo as i128 + 1) as u128;
        if span >= (1u128 << 64) {
            return self.next_u64() as i64; // full 64-bit span
        }
        lo.wrapping_add(self.below(span as u64) as i64)
    }

    /// Uniform f64 in `[0, 1)` with 53 bits of mantissa.
    #[inline]
    pub fn uniform_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn uniform_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Standard normal via Box–Muller (polar-free, two uniforms).
    pub fn normal_f64(&mut self) -> f64 {
        let u1 = self.uniform_f64().max(f64::MIN_POSITIVE);
        let u2 = self.uniform_f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Random 128-bit integer (for the paper's Int128 sorting cases).
    #[inline]
    pub fn next_i128(&mut self) -> i128 {
        ((self.next_u64() as u128) << 64 | self.next_u64() as u128) as i128
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `0..n` (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        let k = k.min(n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below((n - i) as u64) as usize;
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Prng::new(42);
        let mut b = Prng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Prng::new(1);
        let mut b = Prng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn below_is_in_range() {
        let mut r = Prng::new(7);
        for bound in [1u64, 2, 3, 10, 1000, u64::MAX] {
            for _ in 0..64 {
                assert!(r.below(bound) < bound);
            }
        }
    }

    #[test]
    fn range_inclusive_hits_endpoints() {
        let mut r = Prng::new(9);
        let (mut saw_lo, mut saw_hi) = (false, false);
        for _ in 0..10_000 {
            let v = r.range_i64(-3, 3);
            assert!((-3..=3).contains(&v));
            saw_lo |= v == -3;
            saw_hi |= v == 3;
        }
        assert!(saw_lo && saw_hi);
    }

    #[test]
    fn uniform_bounds() {
        let mut r = Prng::new(11);
        for _ in 0..1000 {
            let f = r.uniform_f64();
            assert!((0.0..1.0).contains(&f));
            let g = r.uniform_f32();
            assert!((0.0..1.0).contains(&g));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Prng::new(13);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal_f64()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Prng::new(17);
        let mut xs: Vec<u32> = (0..257).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..257).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Prng::new(19);
        let s = r.sample_indices(100, 20);
        assert_eq!(s.len(), 20);
        let mut t = s.clone();
        t.sort_unstable();
        t.dedup();
        assert_eq!(t.len(), 20);
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Prng::new(23);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
