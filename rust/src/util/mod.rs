//! Foundation utilities: PRNG, statistics, JSON parsing, formatting.
//!
//! These replace crates that are unavailable in the offline build
//! environment (rand, serde_json, humansize) — see DESIGN.md §9.

pub mod failpoint;
pub mod fmt;
pub mod json;
pub mod prng;
pub mod stats;

pub use fmt::{fmt_bytes, fmt_duration, fmt_throughput};
pub use prng::Prng;
pub use stats::Summary;
