//! Configuration system: run presets + a TOML-subset parser.
//!
//! Every benchmark and the `akbench` CLI are driven by a [`RunConfig`]
//! that can be loaded from a config file (`--config path.toml`) and/or
//! overridden by CLI flags. The parser covers the TOML subset the configs
//! use: `[section]` headers, `key = value` with strings, integers,
//! floats, booleans and flat arrays, plus `#` comments (serde/toml are
//! unavailable offline — DESIGN.md §9).

use std::collections::BTreeMap;

use anyhow::{bail, Context};

use crate::cluster::topology::ClusterSpec;
use crate::dtype::ElemType;
use crate::workload::Distribution;

/// A parsed flat-TOML document: section -> key -> raw value.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Toml {
    pub sections: BTreeMap<String, BTreeMap<String, TomlValue>>,
}

/// TOML scalar / flat array value.
#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Arr(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            TomlValue::Int(v) => Some(*v),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Float(v) => Some(*v),
            TomlValue::Int(v) => Some(*v as f64),
            _ => None,
        }
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

impl Toml {
    pub fn parse(text: &str) -> anyhow::Result<Toml> {
        let mut doc = Toml::default();
        let mut section = String::new();
        doc.sections.entry(section.clone()).or_default();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
                section = name.trim().to_string();
                doc.sections.entry(section.clone()).or_default();
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .with_context(|| format!("line {}: expected key = value", lineno + 1))?;
            let value = parse_value(v.trim())
                .with_context(|| format!("line {}: bad value '{}'", lineno + 1, v.trim()))?;
            doc.sections
                .get_mut(&section)
                .unwrap()
                .insert(k.trim().to_string(), value);
        }
        Ok(doc)
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&TomlValue> {
        self.sections.get(section).and_then(|m| m.get(key))
    }
}

fn strip_comment(line: &str) -> &str {
    // '#' outside of quotes starts a comment.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(v: &str) -> anyhow::Result<TomlValue> {
    if let Some(inner) = v.strip_prefix('"').and_then(|s| s.strip_suffix('"')) {
        return Ok(TomlValue::Str(inner.to_string()));
    }
    if v == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if v == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if let Some(inner) = v.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
        let inner = inner.trim();
        if inner.is_empty() {
            return Ok(TomlValue::Arr(vec![]));
        }
        let items = split_top_level(inner)?
            .into_iter()
            .map(|s| parse_value(s.trim()))
            .collect::<anyhow::Result<Vec<_>>>()?;
        return Ok(TomlValue::Arr(items));
    }
    let clean = v.replace('_', "");
    if let Ok(i) = clean.parse::<i64>() {
        return Ok(TomlValue::Int(i));
    }
    if let Ok(f) = clean.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    bail!("unparseable value")
}

fn split_top_level(s: &str) -> anyhow::Result<Vec<&str>> {
    let mut out = Vec::new();
    let mut depth = 0usize;
    let mut in_str = false;
    let mut start = 0usize;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '[' if !in_str => depth += 1,
            ']' if !in_str => depth = depth.checked_sub(1).context("unbalanced ]")?,
            ',' if !in_str && depth == 0 => {
                out.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    out.push(&s[start..]);
    Ok(out)
}

// ---------------------------------------------------------------------------

/// Which local sorter a rank uses (the paper's Fig 1–5 legend, plus the
/// hybrid co-sorter of DESIGN.md §10).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Sorter {
    /// "CC-JB": single-thread CPU comparison sort (Julia Base analog).
    JuliaBase,
    /// "AK": the AcceleratedKernels merge sort — our Pallas/XLA artifact.
    Ak,
    /// "TM": vendor merge sort (Thrust analog, native optimised).
    ThrustMerge,
    /// "TR": vendor radix sort (Thrust analog, native optimised).
    ThrustRadix,
    /// "HY": hybrid CPU–GPU co-sort — the rank's host threads and its
    /// device engine sort disjoint sub-shards concurrently and merge
    /// (`crate::hybrid`, DESIGN.md §10).
    Hybrid,
    /// "EX": out-of-core external sorter — each rank's shard streams
    /// through `stream::external_sort` under a memory budget, so a rank
    /// handles shards larger than its RAM (`--local-sorter external`,
    /// DESIGN.md §14).
    External,
}

impl Sorter {
    /// The paper's Fig 1–5 legend (the hybrid co-sorter is this repo's
    /// extension and is listed separately as Fig 6).
    pub const ALL: [Sorter; 4] =
        [Sorter::JuliaBase, Sorter::Ak, Sorter::ThrustMerge, Sorter::ThrustRadix];

    /// Paper legend code ("JB", "AK", "TM", "TR", "HY", "EX").
    pub fn code(self) -> &'static str {
        match self {
            Sorter::JuliaBase => "JB",
            Sorter::Ak => "AK",
            Sorter::ThrustMerge => "TM",
            Sorter::ThrustRadix => "TR",
            Sorter::Hybrid => "HY",
            Sorter::External => "EX",
        }
    }

    /// Parse a legend code or long name (case-insensitive).
    pub fn parse(s: &str) -> Option<Sorter> {
        match s.to_ascii_uppercase().as_str() {
            "JB" | "JULIABASE" | "BASE" => Some(Sorter::JuliaBase),
            "AK" => Some(Sorter::Ak),
            "TM" | "THRUSTMERGE" => Some(Sorter::ThrustMerge),
            "TR" | "THRUSTRADIX" => Some(Sorter::ThrustRadix),
            "HY" | "HYBRID" => Some(Sorter::Hybrid),
            "EX" | "EXTERNAL" => Some(Sorter::External),
            _ => None,
        }
    }

    /// GPU-class sorter? (JB runs on a CPU rank, as does the streaming
    /// external sorter; a hybrid rank owns a device, so it is
    /// device-class for link selection and Fig 5 normalisation.)
    pub fn is_device(self) -> bool {
        !matches!(self, Sorter::JuliaBase | Sorter::External)
    }
}

/// Execution backend selector for the algorithm suite (`--backend`,
/// `[run] backend` in config files).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum BackendKind {
    /// Single-thread host execution.
    Native,
    /// Host thread pool.
    Threaded,
    /// AOT artifacts through PJRT.
    Device,
    /// CPU–GPU co-processing (DESIGN.md §10).
    Hybrid,
}

impl BackendKind {
    /// Every selectable backend.
    pub const ALL: [BackendKind; 4] =
        [BackendKind::Native, BackendKind::Threaded, BackendKind::Device, BackendKind::Hybrid];

    /// CLI / config-file name.
    pub fn name(self) -> &'static str {
        match self {
            BackendKind::Native => "native",
            BackendKind::Threaded => "threaded",
            BackendKind::Device => "device",
            BackendKind::Hybrid => "hybrid",
        }
    }

    /// Parse a CLI / config-file name (case-insensitive).
    pub fn parse(s: &str) -> Option<BackendKind> {
        match s.to_ascii_lowercase().as_str() {
            "native" => Some(BackendKind::Native),
            "threaded" | "cpu" => Some(BackendKind::Threaded),
            "device" | "gpu" => Some(BackendKind::Device),
            "hybrid" => Some(BackendKind::Hybrid),
            _ => None,
        }
    }

    /// The rank-local sorter this backend implies for distributed runs:
    /// host backends sort like a CPU rank, `device` like an AK rank,
    /// `hybrid` co-sorts.
    pub fn sorter(self) -> Sorter {
        match self {
            BackendKind::Native | BackendKind::Threaded => Sorter::JuliaBase,
            BackendKind::Device => Sorter::Ak,
            BackendKind::Hybrid => Sorter::Hybrid,
        }
    }
}

/// MPI transfer mode (the paper's "GC-" vs "GG-" prefixes).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum TransferMode {
    /// Communication staged through host RAM (device-to-host copy first).
    CpuStaged,
    /// GPUDirect over NVLink/IB: device buffers move without host staging.
    GpuDirect,
}

impl TransferMode {
    pub const ALL: [TransferMode; 2] = [TransferMode::CpuStaged, TransferMode::GpuDirect];

    /// Paper legend prefix ("GC" / "GG"), or "CC" for CPU sorters.
    pub fn prefix(self, sorter: Sorter) -> &'static str {
        if !sorter.is_device() {
            return "CC";
        }
        match self {
            TransferMode::CpuStaged => "GC",
            TransferMode::GpuDirect => "GG",
        }
    }

    pub fn parse(s: &str) -> Option<TransferMode> {
        match s.to_ascii_lowercase().as_str() {
            "staged" | "cpu" | "gc" => Some(TransferMode::CpuStaged),
            "direct" | "nvlink" | "gpudirect" | "gg" => Some(TransferMode::GpuDirect),
            _ => None,
        }
    }
}

/// Final-phase strategy for SIHSort (ablated; the paper re-sorts).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum FinalPhase {
    /// K-way merge the received sorted runs (our default).
    Merge,
    /// Full second local sort (the paper's description).
    Sort,
}

/// Streaming / out-of-core settings (`[stream]` config section and the
/// `bench-stream` CLI flags — DESIGN.md §13).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct StreamCfg {
    /// Spill sorted runs to memory instead of disk (`spill = "memory"`;
    /// the default medium is temp-file spill).
    pub spill_memory: bool,
    /// Parent directory for the guarded spill directories (`spill_dir`;
    /// default: the OS temp dir). Points at fast scratch storage on
    /// cluster nodes.
    pub spill_dir: Option<String>,
    /// Per-rank engine-state budget in bytes for the external local
    /// sorter (`budget_mb` / `--stream-budget-mb`, stored in bytes).
    /// `None`: the driver defaults to a quarter of the per-rank shard,
    /// so `--local-sorter external` actually streams out of core.
    pub budget_bytes: Option<usize>,
    /// Durable checkpoint root for crash-safe external/cluster sorts
    /// (`checkpoint` / `--checkpoint-dir` — DESIGN.md §15). Requires
    /// the external sorter on every rank.
    pub checkpoint_dir: Option<String>,
    /// Resume from the manifests under `checkpoint_dir` instead of
    /// starting fresh (`resume = true` / `--resume`).
    pub resume: bool,
}

impl StreamCfg {
    /// Parse a `spill = "disk"|"memory"` value.
    pub fn parse_spill(v: &str) -> anyhow::Result<bool> {
        match v {
            "memory" => Ok(true),
            "disk" => Ok(false),
            other => bail!("spill: expected disk|memory, got '{other}'"),
        }
    }
}

/// Fabric transport settings (`[comm]` section and CLI flags —
/// DESIGN.md §16): per-link credit caps, blocking-wait deadlines, the
/// sender retry policy, deterministic fault injection, and the driver's
/// restart/watchdog budget.
#[derive(Clone, Debug, PartialEq)]
pub struct CommCfg {
    /// In-flight credit cap per NVLink hop (MB; `cap_nvlink_mb`).
    pub cap_nvlink_mb: f64,
    /// In-flight credit cap per InfiniBand hop (MB; `cap_ib_mb`).
    pub cap_ib_mb: f64,
    /// In-flight credit cap per PCIe hop (MB; `cap_pcie_mb`).
    pub cap_pcie_mb: f64,
    /// In-flight credit cap per host-memory hop (MB; `cap_hostmem_mb`).
    pub cap_hostmem_mb: f64,
    /// Deadline of every blocking receive / barrier (wall seconds).
    pub recv_timeout_secs: f64,
    /// Deadline of a credit-blocked send (wall seconds).
    pub send_timeout_secs: f64,
    /// Sender retry attempts per message on retryable comm timeouts.
    pub retry_attempts: u32,
    /// First-retry backoff (simulated seconds; doubles per attempt).
    pub retry_base_secs: f64,
    /// Driver watchdog: wall seconds before a hung collective is
    /// aborted and reported with per-rank diagnostics.
    pub watchdog_secs: f64,
    /// In-process restart attempts after a recoverable rank death
    /// (`--max-restarts`; checkpointed ranks resume from manifests).
    pub max_restarts: u32,
    /// Deterministic link/rank fault spec (`--faults`; see
    /// [`crate::comm::FaultPlan::parse`] for the grammar).
    pub faults: Option<String>,
    /// Seed for the fault plan's deterministic draws (`--fault-seed`).
    pub fault_seed: u64,
    /// Happens-before / deadlock-detector debug mode (`hb_check` /
    /// `--hb-check`; see [`crate::comm::CommTuning::hb_check`]).
    pub hb_check: bool,
}

impl Default for CommCfg {
    fn default() -> Self {
        Self {
            cap_nvlink_mb: 64.0,
            cap_ib_mb: 64.0,
            cap_pcie_mb: 64.0,
            cap_hostmem_mb: 64.0,
            recv_timeout_secs: 600.0,
            send_timeout_secs: 600.0,
            retry_attempts: 4,
            retry_base_secs: 1e-4,
            watchdog_secs: 300.0,
            max_restarts: 0,
            faults: None,
            fault_seed: 0,
            hb_check: false,
        }
    }
}

impl CommCfg {
    /// Set every per-link credit cap at once (`cap_mb` /
    /// `--comm-cap-mb`).
    pub fn set_all_caps_mb(&mut self, mb: f64) {
        self.cap_nvlink_mb = mb;
        self.cap_ib_mb = mb;
        self.cap_pcie_mb = mb;
        self.cap_hostmem_mb = mb;
    }

    /// The parsed fault plan, if a spec is configured.
    pub fn fault_plan(&self) -> anyhow::Result<Option<crate::comm::FaultPlan>> {
        self.faults
            .as_deref()
            .map(|s| crate::comm::FaultPlan::parse(s, self.fault_seed))
            .transpose()
    }

    /// Build the fabric tuning these knobs describe (epoch 0, no fault
    /// state — the driver attaches both per restart attempt).
    pub fn tuning(&self) -> crate::comm::CommTuning {
        let mb = |v: f64| ((v * 1e6) as usize).max(1);
        crate::comm::CommTuning {
            cap_nvlink: mb(self.cap_nvlink_mb),
            cap_ib: mb(self.cap_ib_mb),
            cap_pcie: mb(self.cap_pcie_mb),
            cap_hostmem: mb(self.cap_hostmem_mb),
            recv_timeout_secs: self.recv_timeout_secs,
            send_timeout_secs: self.send_timeout_secs,
            retry: crate::comm::RetryPolicy {
                max_attempts: self.retry_attempts,
                base_secs: self.retry_base_secs,
                ..crate::comm::RetryPolicy::default()
            },
            faults: None,
            epoch: 0,
            hb_check: self.hb_check,
        }
    }
}

/// Observability settings (`[obs]` config section and the
/// `--trace-out` / `--trace-summary` CLI flags — DESIGN.md §18).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ObsCfg {
    /// Chrome/Perfetto trace-event JSON output path (`trace_out` /
    /// `--trace-out`). `None` leaves the tracer disarmed.
    pub trace_out: Option<String>,
    /// Print the human phase table after the run (`trace_summary` /
    /// `--trace-summary`). Arms the tracer even without `trace_out`.
    pub trace_summary: bool,
    /// Per-thread trace ring capacity in events (`ring_capacity`).
    pub ring_capacity: usize,
}

impl Default for ObsCfg {
    fn default() -> Self {
        Self {
            trace_out: None,
            trace_summary: false,
            ring_capacity: crate::obs::tracer::DEFAULT_RING_CAPACITY,
        }
    }
}

impl ObsCfg {
    /// True when any output is requested — the condition under which
    /// `main` arms a [`crate::obs::TraceSession`] around the command.
    pub fn armed(&self) -> bool {
        self.trace_out.is_some() || self.trace_summary
    }
}

/// Top-level run configuration (CLI + config file).
#[derive(Clone, Debug)]
pub struct RunConfig {
    /// Simulated cluster shape + link parameters.
    pub cluster: ClusterSpec,
    /// Number of simulated ranks.
    pub ranks: usize,
    /// Element type of the sorted keys.
    pub dtype: ElemType,
    /// Workload distribution.
    pub dist: Distribution,
    /// Rank-local sorting engine.
    pub sorter: Sorter,
    /// MPI transfer mode (GPUDirect vs host-staged).
    pub transfer: TransferMode,
    /// SIHSort final-phase strategy.
    pub final_phase: FinalPhase,
    /// Elements per rank (weak scaling) — converted from --mb-per-rank.
    pub elems_per_rank: usize,
    /// Workload seed.
    pub seed: u64,
    /// Oversampling factor for splitter sampling (paper's sample sort p).
    pub samples_per_rank: usize,
    /// Max splitter-refinement rounds (interpolated histograms).
    pub refine_rounds: usize,
    /// Bucket balance tolerance (fraction of ideal bucket size).
    pub balance_tol: f64,
    /// Backend selected via `--backend` / `[run] backend`, if any. Its
    /// only effect is to imply the rank-local sorter at parse time
    /// ([`BackendKind::sorter`]); no command reads the field itself.
    pub backend: Option<BackendKind>,
    /// Host thread-pool width for hybrid ranks (DESIGN.md §10).
    pub host_threads: usize,
    /// Fixed hybrid host fraction (`--host-fraction`); `None` means the
    /// driver calibrates the split (`hybrid::calibrate`).
    pub hybrid_host_fraction: Option<f64>,
    /// Per-call tuning knobs for every rank-local sort and recombine
    /// (`--block-size` / `--max-tasks` / `--min-elems-per-task` /
    /// `--par-threshold` / `--reuse-scratch`; `[run]` keys of the same
    /// names — the `Session`/`Launch` API of DESIGN.md §12).
    pub launch: crate::session::Launch,
    /// Streaming / out-of-core settings (`[stream]` section and the
    /// `bench-stream` flags — DESIGN.md §13).
    pub stream: StreamCfg,
    /// Fabric transport settings (`[comm]` section — DESIGN.md §16).
    pub comm: CommCfg,
    /// Observability settings (`[obs]` section — DESIGN.md §18).
    pub obs: ObsCfg,
}

impl Default for RunConfig {
    fn default() -> Self {
        Self {
            cluster: ClusterSpec::baskerville(),
            ranks: 8,
            dtype: ElemType::I32,
            dist: Distribution::Uniform,
            sorter: Sorter::Ak,
            transfer: TransferMode::GpuDirect,
            final_phase: FinalPhase::Merge,
            elems_per_rank: 1 << 20,
            seed: 42,
            samples_per_rank: 64,
            refine_rounds: 4,
            balance_tol: 0.10,
            backend: None,
            host_threads: crate::backend::threaded::default_threads(),
            hybrid_host_fraction: None,
            launch: crate::session::Launch::default(),
            stream: StreamCfg::default(),
            comm: CommCfg::default(),
            obs: ObsCfg::default(),
        }
    }
}

impl RunConfig {
    /// Apply `[run]` and `[cluster]` sections of a config file.
    pub fn apply_toml(&mut self, doc: &Toml) -> anyhow::Result<()> {
        if let Some(v) = doc.get("run", "ranks").and_then(|v| v.as_i64()) {
            self.ranks = v as usize;
        }
        if let Some(v) = doc.get("run", "dtype").and_then(|v| v.as_str()) {
            self.dtype = ElemType::parse(v).with_context(|| format!("bad dtype {v}"))?;
        }
        if let Some(v) = doc.get("run", "dist").and_then(|v| v.as_str()) {
            self.dist = Distribution::parse(v).with_context(|| format!("bad dist {v}"))?;
        }
        // `backend` implies a sorter, but an explicit `sorter` key wins —
        // the same precedence the CLI gives --backend vs --sorter.
        if let Some(v) = doc.get("run", "backend").and_then(|v| v.as_str()) {
            let kind = BackendKind::parse(v).with_context(|| format!("bad backend {v}"))?;
            self.backend = Some(kind);
            self.sorter = kind.sorter();
        }
        if let Some(v) = doc.get("run", "sorter").and_then(|v| v.as_str()) {
            self.sorter = Sorter::parse(v).with_context(|| format!("bad sorter {v}"))?;
        }
        if let Some(v) = doc.get("run", "transfer").and_then(|v| v.as_str()) {
            self.transfer = TransferMode::parse(v).with_context(|| format!("bad transfer {v}"))?;
        }
        if let Some(v) = doc.get("run", "elems_per_rank").and_then(|v| v.as_i64()) {
            self.elems_per_rank = v as usize;
        }
        if let Some(v) = doc.get("run", "seed").and_then(|v| v.as_i64()) {
            self.seed = v as u64;
        }
        if let Some(v) = doc.get("run", "samples_per_rank").and_then(|v| v.as_i64()) {
            self.samples_per_rank = v as usize;
        }
        if let Some(v) = doc.get("run", "refine_rounds").and_then(|v| v.as_i64()) {
            self.refine_rounds = v as usize;
        }
        if let Some(v) = doc.get("run", "balance_tol").and_then(|v| v.as_f64()) {
            self.balance_tol = v;
        }
        if let Some(v) = doc.get("run", "host_threads").and_then(|v| v.as_i64()) {
            self.host_threads = (v as usize).max(1);
        }
        if let Some(v) = doc.get("run", "host_fraction").and_then(|v| v.as_f64()) {
            anyhow::ensure!((0.0..=1.0).contains(&v), "host_fraction {v} outside [0, 1]");
            self.hybrid_host_fraction = Some(v);
        }
        // Launch knobs ([run] section, same names as the CLI flags).
        if let Some(v) = doc.get("run", "block_size").and_then(|v| v.as_i64()) {
            self.launch.block_size = Some((v.max(1)) as usize);
        }
        if let Some(v) = doc.get("run", "max_tasks").and_then(|v| v.as_i64()) {
            self.launch.max_tasks = Some((v.max(1)) as usize);
        }
        if let Some(v) = doc.get("run", "min_elems_per_task").and_then(|v| v.as_i64()) {
            self.launch.min_elems_per_task = Some((v.max(1)) as usize);
        }
        if let Some(v) = doc.get("run", "par_threshold").and_then(|v| v.as_i64()) {
            self.launch.prefer_parallel_threshold = Some(v.max(0) as usize);
        }
        if let Some(v) = doc.get("run", "reuse_scratch").and_then(|v| v.as_bool()) {
            self.launch.reuse_scratch = Some(v);
        }
        // Streaming settings ([stream] section — DESIGN.md §13).
        if let Some(v) = doc.get("stream", "spill").and_then(|v| v.as_str()) {
            self.stream.spill_memory = StreamCfg::parse_spill(v)?;
        }
        if let Some(v) = doc.get("stream", "spill_dir").and_then(|v| v.as_str()) {
            self.stream.spill_dir = Some(v.to_string());
        }
        if let Some(v) = doc.get("stream", "budget_mb").and_then(|v| v.as_f64()) {
            anyhow::ensure!(v > 0.0, "budget_mb must be positive, got {v}");
            self.stream.budget_bytes = Some(((v * 1e6) as usize).max(1));
        }
        if let Some(v) = doc.get("stream", "checkpoint").and_then(|v| v.as_str()) {
            self.stream.checkpoint_dir = Some(v.to_string());
        }
        if let Some(v) = doc.get("stream", "resume").and_then(|v| v.as_bool()) {
            self.stream.resume = v;
        }
        // Fabric transport settings ([comm] section — DESIGN.md §16).
        if let Some(v) = doc.get("comm", "cap_mb").and_then(|v| v.as_f64()) {
            anyhow::ensure!(v > 0.0, "comm cap_mb must be positive, got {v}");
            self.comm.set_all_caps_mb(v);
        }
        for (key, slot) in [
            ("cap_nvlink_mb", 0usize),
            ("cap_ib_mb", 1),
            ("cap_pcie_mb", 2),
            ("cap_hostmem_mb", 3),
        ] {
            if let Some(v) = doc.get("comm", key).and_then(|v| v.as_f64()) {
                anyhow::ensure!(v > 0.0, "comm {key} must be positive, got {v}");
                match slot {
                    0 => self.comm.cap_nvlink_mb = v,
                    1 => self.comm.cap_ib_mb = v,
                    2 => self.comm.cap_pcie_mb = v,
                    _ => self.comm.cap_hostmem_mb = v,
                }
            }
        }
        if let Some(v) = doc.get("comm", "recv_timeout_secs").and_then(|v| v.as_f64()) {
            anyhow::ensure!(v > 0.0, "comm recv_timeout_secs must be positive, got {v}");
            self.comm.recv_timeout_secs = v;
        }
        if let Some(v) = doc.get("comm", "send_timeout_secs").and_then(|v| v.as_f64()) {
            anyhow::ensure!(v > 0.0, "comm send_timeout_secs must be positive, got {v}");
            self.comm.send_timeout_secs = v;
        }
        if let Some(v) = doc.get("comm", "retry_attempts").and_then(|v| v.as_i64()) {
            self.comm.retry_attempts = (v.max(1)) as u32;
        }
        if let Some(v) = doc.get("comm", "retry_base_secs").and_then(|v| v.as_f64()) {
            anyhow::ensure!(v > 0.0, "comm retry_base_secs must be positive, got {v}");
            self.comm.retry_base_secs = v;
        }
        if let Some(v) = doc.get("comm", "watchdog_secs").and_then(|v| v.as_f64()) {
            anyhow::ensure!(v > 0.0, "comm watchdog_secs must be positive, got {v}");
            self.comm.watchdog_secs = v;
        }
        if let Some(v) = doc.get("comm", "max_restarts").and_then(|v| v.as_i64()) {
            self.comm.max_restarts = (v.max(0)) as u32;
        }
        if let Some(v) = doc.get("comm", "faults").and_then(|v| v.as_str()) {
            self.comm.faults = Some(v.to_string());
        }
        if let Some(v) = doc.get("comm", "fault_seed").and_then(|v| v.as_i64()) {
            self.comm.fault_seed = v as u64;
        }
        if let Some(v) = doc.get("comm", "hb_check").and_then(|v| v.as_bool()) {
            self.comm.hb_check = v;
        }
        // Observability settings ([obs] section — DESIGN.md §18).
        if let Some(v) = doc.get("obs", "trace_out").and_then(|v| v.as_str()) {
            self.obs.trace_out = Some(v.to_string());
        }
        if let Some(v) = doc.get("obs", "trace_summary").and_then(|v| v.as_bool()) {
            self.obs.trace_summary = v;
        }
        if let Some(v) = doc.get("obs", "ring_capacity").and_then(|v| v.as_i64()) {
            anyhow::ensure!(v > 0, "obs ring_capacity must be positive, got {v}");
            self.obs.ring_capacity = v as usize;
        }
        // Fail at config time, not mid-run, on an unparsable fault spec.
        self.comm.fault_plan()?;
        self.cluster.apply_toml(doc)?;
        Ok(())
    }

    /// Total bytes sorted in this configuration.
    pub fn total_bytes(&self) -> usize {
        self.ranks * self.elems_per_rank * self.dtype.size_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_values() {
        let doc = Toml::parse(
            r#"
            # comment
            top = 1
            [run]
            ranks = 16          # trailing comment
            dtype = "i64"
            balance_tol = 0.05
            flags = [1, 2, 3]
            name = "weak # not a comment"
            ok = true
            "#,
        )
        .unwrap();
        assert_eq!(doc.get("", "top").unwrap().as_i64(), Some(1));
        assert_eq!(doc.get("run", "ranks").unwrap().as_i64(), Some(16));
        assert_eq!(doc.get("run", "dtype").unwrap().as_str(), Some("i64"));
        assert_eq!(doc.get("run", "balance_tol").unwrap().as_f64(), Some(0.05));
        assert_eq!(
            doc.get("run", "flags").unwrap(),
            &TomlValue::Arr(vec![TomlValue::Int(1), TomlValue::Int(2), TomlValue::Int(3)])
        );
        assert_eq!(doc.get("run", "name").unwrap().as_str(), Some("weak # not a comment"));
        assert_eq!(doc.get("run", "ok").unwrap().as_bool(), Some(true));
    }

    #[test]
    fn config_apply() {
        let doc = Toml::parse("[run]\nranks = 32\ndtype = \"f64\"\nsorter = \"TR\"\ntransfer = \"staged\"\n").unwrap();
        let mut cfg = RunConfig::default();
        cfg.apply_toml(&doc).unwrap();
        assert_eq!(cfg.ranks, 32);
        assert_eq!(cfg.dtype, ElemType::F64);
        assert_eq!(cfg.sorter, Sorter::ThrustRadix);
        assert_eq!(cfg.transfer, TransferMode::CpuStaged);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Toml::parse("novalue").is_err());
        assert!(Toml::parse("x = @@").is_err());
    }

    #[test]
    fn stream_section_via_toml() {
        let doc = Toml::parse(
            "[stream]\nspill = \"memory\"\nspill_dir = \"/scratch/ak\"\nbudget_mb = 64\n\
             checkpoint = \"/scratch/ckpt\"\nresume = true\n",
        )
        .unwrap();
        let mut cfg = RunConfig::default();
        assert!(!cfg.stream.spill_memory);
        assert_eq!(cfg.stream.budget_bytes, None);
        assert_eq!(cfg.stream.checkpoint_dir, None);
        assert!(!cfg.stream.resume);
        cfg.apply_toml(&doc).unwrap();
        assert!(cfg.stream.spill_memory);
        assert_eq!(cfg.stream.spill_dir.as_deref(), Some("/scratch/ak"));
        assert_eq!(cfg.stream.budget_bytes, Some(64_000_000));
        assert_eq!(cfg.stream.checkpoint_dir.as_deref(), Some("/scratch/ckpt"));
        assert!(cfg.stream.resume);
        // Non-positive budgets are rejected.
        let bad = Toml::parse("[stream]\nbudget_mb = 0\n").unwrap();
        assert!(RunConfig::default().apply_toml(&bad).is_err());
        // Bad medium values are rejected.
        let bad = Toml::parse("[stream]\nspill = \"tape\"\n").unwrap();
        assert!(RunConfig::default().apply_toml(&bad).is_err());
        assert!(StreamCfg::parse_spill("disk").is_ok_and(|m| !m));
    }

    #[test]
    fn comm_section_via_toml() {
        let doc = Toml::parse(
            "[comm]\ncap_mb = 8\ncap_ib_mb = 2.5\nrecv_timeout_secs = 30\n\
             retry_attempts = 6\nwatchdog_secs = 45\nmax_restarts = 2\n\
             faults = \"flaky:0:1:0.25, kill:1:3:exchange\"\nfault_seed = 7\n\
             hb_check = true\n",
        )
        .unwrap();
        let mut cfg = RunConfig::default();
        assert_eq!(cfg.comm, CommCfg::default());
        cfg.apply_toml(&doc).unwrap();
        assert_eq!(cfg.comm.cap_nvlink_mb, 8.0);
        assert_eq!(cfg.comm.cap_ib_mb, 2.5, "specific cap overrides the blanket cap_mb");
        assert_eq!(cfg.comm.cap_pcie_mb, 8.0);
        assert_eq!(cfg.comm.recv_timeout_secs, 30.0);
        assert_eq!(cfg.comm.retry_attempts, 6);
        assert_eq!(cfg.comm.watchdog_secs, 45.0);
        assert_eq!(cfg.comm.max_restarts, 2);
        assert_eq!(cfg.comm.fault_seed, 7);
        assert!(cfg.comm.hb_check);
        let plan = cfg.comm.fault_plan().unwrap().expect("spec parsed");
        assert_eq!(plan.rules.len(), 2);
        // The tuning carries the caps in bytes and the retry policy.
        let t = cfg.comm.tuning();
        assert_eq!(t.cap_nvlink, 8_000_000);
        assert_eq!(t.cap_ib, 2_500_000);
        assert_eq!(t.retry.max_attempts, 6);
        assert!(t.hb_check, "hb_check must flow into the fabric tuning");
        // Unparsable fault specs fail at config time.
        let bad = Toml::parse("[comm]\nfaults = \"melt:0\"\n").unwrap();
        assert!(RunConfig::default().apply_toml(&bad).is_err());
        // Non-positive caps are rejected.
        let bad = Toml::parse("[comm]\ncap_mb = 0\n").unwrap();
        assert!(RunConfig::default().apply_toml(&bad).is_err());
    }

    #[test]
    fn obs_section_via_toml() {
        let doc = Toml::parse(
            "[obs]\ntrace_out = \"target/trace.json\"\ntrace_summary = true\n\
             ring_capacity = 4096\n",
        )
        .unwrap();
        let mut cfg = RunConfig::default();
        assert_eq!(cfg.obs, ObsCfg::default());
        assert!(!cfg.obs.armed());
        cfg.apply_toml(&doc).unwrap();
        assert_eq!(cfg.obs.trace_out.as_deref(), Some("target/trace.json"));
        assert!(cfg.obs.trace_summary);
        assert_eq!(cfg.obs.ring_capacity, 4096);
        assert!(cfg.obs.armed());
        // A summary alone also arms the tracer.
        let mut summary_only = RunConfig::default();
        summary_only.obs.trace_summary = true;
        assert!(summary_only.obs.armed());
        // Non-positive ring capacities are rejected.
        let bad = Toml::parse("[obs]\nring_capacity = 0\n").unwrap();
        assert!(RunConfig::default().apply_toml(&bad).is_err());
    }

    #[test]
    fn sorter_codes() {
        assert_eq!(Sorter::parse("tr"), Some(Sorter::ThrustRadix));
        assert_eq!(Sorter::parse("hybrid"), Some(Sorter::Hybrid));
        assert_eq!(Sorter::Hybrid.code(), "HY");
        assert!(Sorter::Hybrid.is_device());
        assert_eq!(Sorter::parse("external"), Some(Sorter::External));
        assert_eq!(Sorter::parse("ex"), Some(Sorter::External));
        assert_eq!(Sorter::External.code(), "EX");
        assert!(!Sorter::External.is_device(), "external ranks are CPU-class");
        assert_eq!(TransferMode::GpuDirect.prefix(Sorter::External), "CC");
        assert_eq!(TransferMode::GpuDirect.prefix(Sorter::Ak), "GG");
        assert_eq!(TransferMode::CpuStaged.prefix(Sorter::Ak), "GC");
        assert_eq!(TransferMode::GpuDirect.prefix(Sorter::JuliaBase), "CC");
        assert_eq!(TransferMode::GpuDirect.prefix(Sorter::Hybrid), "GG");
    }

    #[test]
    fn backend_kinds_parse_and_imply_sorters() {
        for k in BackendKind::ALL {
            assert_eq!(BackendKind::parse(k.name()), Some(k));
        }
        assert_eq!(BackendKind::parse("GPU"), Some(BackendKind::Device));
        assert_eq!(BackendKind::parse("nope"), None);
        assert_eq!(BackendKind::Hybrid.sorter(), Sorter::Hybrid);
        assert_eq!(BackendKind::Device.sorter(), Sorter::Ak);
        assert_eq!(BackendKind::Native.sorter(), Sorter::JuliaBase);
    }

    #[test]
    fn hybrid_config_via_toml() {
        let doc = Toml::parse(
            "[run]\nbackend = \"hybrid\"\nhost_threads = 6\nhost_fraction = 0.25\n",
        )
        .unwrap();
        let mut cfg = RunConfig::default();
        cfg.apply_toml(&doc).unwrap();
        assert_eq!(cfg.backend, Some(BackendKind::Hybrid));
        assert_eq!(cfg.sorter, Sorter::Hybrid);
        assert_eq!(cfg.host_threads, 6);
        assert_eq!(cfg.hybrid_host_fraction, Some(0.25));

        let bad = Toml::parse("[run]\nhost_fraction = 1.5\n").unwrap();
        assert!(RunConfig::default().apply_toml(&bad).is_err());
    }

    #[test]
    fn launch_knobs_via_toml() {
        let doc = Toml::parse(
            "[run]\nmax_tasks = 3\nmin_elems_per_task = 4096\npar_threshold = 1000\nblock_size = 65536\nreuse_scratch = true\n",
        )
        .unwrap();
        let mut cfg = RunConfig::default();
        cfg.apply_toml(&doc).unwrap();
        assert_eq!(cfg.launch.max_tasks, Some(3));
        assert_eq!(cfg.launch.min_elems_per_task, Some(4096));
        assert_eq!(cfg.launch.prefer_parallel_threshold, Some(1000));
        assert_eq!(cfg.launch.block_size, Some(65536));
        assert_eq!(cfg.launch.reuse_scratch, Some(true));
    }

    #[test]
    fn toml_sorter_wins_over_backend_like_cli() {
        // Same precedence as `--backend hybrid --sorter TR`.
        let doc =
            Toml::parse("[run]\nsorter = \"TR\"\nbackend = \"hybrid\"\n").unwrap();
        let mut cfg = RunConfig::default();
        cfg.apply_toml(&doc).unwrap();
        assert_eq!(cfg.backend, Some(BackendKind::Hybrid));
        assert_eq!(cfg.sorter, Sorter::ThrustRadix);
    }
}
