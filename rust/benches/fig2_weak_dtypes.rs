//! Bench: paper Fig 2 — weak scaling at fixed bytes/rank across all six
//! dtypes and the GPU sorter×transfer grid (1 GB/rank in the paper;
//! default 2 MB/rank here, override AK_FIG2_BYTES_PER_RANK).

use accelkern::cfg::RunConfig;
use accelkern::dtype::ElemType;
use accelkern::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let base = RunConfig::default();
    let rt = Runtime::open_default().ok();
    let bytes = std::env::var("AK_FIG2_BYTES_PER_RANK")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(2 << 20);
    let ranks = [4usize, 8, 16, 32, 64];
    accelkern::coordinator::campaign::fig2(&base, &ranks, bytes, &ElemType::ALL, &rt)?;
    Ok(())
}
