//! Bench: paper Table II — RBF + LJG arithmetic kernels across the
//! implementation/device matrix. `cargo bench --bench table2_arithmetic`
//! (env: AK_BENCH_N, AK_BENCH_THREADS, AK_BENCH_SCALE).

use accelkern::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let n: usize = std::env::var("AK_BENCH_N").ok().and_then(|s| s.parse().ok()).unwrap_or(1 << 21);
    let threads: usize = std::env::var("AK_BENCH_THREADS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or_else(accelkern::backend::threaded::default_threads);
    let rt = match Runtime::open_default() {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("warn: no artifacts ({e}); device rows skipped");
            None
        }
    };
    accelkern::coordinator::campaign::table2(n, threads, &rt, false)
}
