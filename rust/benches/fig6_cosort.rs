//! Bench: Fig 6 (this repo's extension) — hybrid CPU–GPU co-sort.
//!
//! Panel (a): single-shard co-sort throughput vs the host-only engine at
//! growing n, for the calibrated split and a fixed 50/50 split.
//! Panel (b): weak scaling of distributed SIHSort with HY (hybrid
//! co-sorting) ranks against homogeneous vendor-radix ranks.
//!
//! Env: `AK_FIG6_QUICK=1` shrinks both grids for CI smoke runs.

use std::time::Instant;

use accelkern::backend::Backend;
use accelkern::cfg::{RunConfig, Sorter};
use accelkern::cluster::DeviceModel;
use accelkern::coordinator::driver::run_distributed_sort;
use accelkern::hybrid::{calibrate_sort, co_sort, HybridEngine, HybridPlan};
use accelkern::metrics::{dump_csv, render_series_table, Series};
use accelkern::runtime::{Registry, Runtime};
use accelkern::util::Prng;
use accelkern::workload::{generate, Distribution};

fn main() -> anyhow::Result<()> {
    let quick = std::env::var("AK_FIG6_QUICK").is_ok();
    let host_threads = accelkern::backend::threaded::default_threads();
    let rt = Runtime::open_default().ok();
    if rt.is_none() {
        eprintln!("warn: no artifacts; the device engine runs its host stand-in");
    }
    let device_backend = rt.clone().map(|rt| Backend::device(Registry::new(rt)));

    // Calibrate once; every plan derives from the same measurement.
    let dev_ops = device_backend.as_ref().and_then(|b| b.device_ops());
    let cal = calibrate_sort::<i64>(1 << 16, host_threads, dev_ops)?;
    let dm = DeviceModel::default();
    // Split for the engines as they actually execute (panel (a) measures
    // wall clock); the model projection is informational.
    let plan = cal.plan_measured(1.0);
    eprintln!(
        "calibrated split: {:.1}% host (host {:.2} Melem/s, model-projected device:host {:.1}x)",
        plan.host_fraction * 100.0,
        cal.host_elems_per_sec / 1e6,
        cal.ratio(&dm)
    );

    // ---- Panel (a): single-shard co-sort throughput ------------------------
    let sizes: Vec<usize> =
        if quick { vec![1 << 15, 1 << 17] } else { vec![1 << 15, 1 << 17, 1 << 19, 1 << 21] };
    let reps = if quick { 2 } else { 3 };
    let engines: Vec<(&str, HybridEngine)> = vec![
        ("host-only", HybridEngine::new(HybridPlan::host_only(), host_threads, None)),
        (
            "hybrid-calibrated",
            HybridEngine::from_backends(plan, host_threads, device_backend.clone()),
        ),
        (
            "hybrid-50/50",
            HybridEngine::from_backends(HybridPlan::new(0.5), host_threads, device_backend.clone()),
        ),
    ];
    let mut shard_series: Vec<Series> =
        engines.iter().map(|(name, _)| Series::new(*name)).collect();
    for &n in &sizes {
        let xs: Vec<i64> = generate(&mut Prng::new(42), Distribution::Uniform, n);
        for (si, (_, eng)) in engines.iter().enumerate() {
            let mut best = f64::INFINITY;
            for _ in 0..reps {
                let mut buf = xs.clone();
                let t0 = Instant::now();
                co_sort(eng, &mut buf)?;
                best = best.min(t0.elapsed().as_secs_f64());
            }
            shard_series[si].push(n as f64, n as f64 / best);
        }
    }
    println!(
        "{}",
        render_series_table("Fig 6a — co-sort single-shard throughput", "n", "elems/s", &shard_series)
    );
    dump_csv("fig6_cosort_shard", &shard_series);

    // ---- Panel (b): weak scaling with hybrid ranks -------------------------
    let rank_grid: Vec<usize> = if quick { vec![2, 4] } else { vec![4, 8, 16] };
    let elems_per_rank = if quick { 20_000 } else { 100_000 };
    let mut weak = vec![Series::new("GG-HY"), Series::new("GG-TR")];
    for &ranks in &rank_grid {
        let mut cfg = RunConfig::default();
        cfg.ranks = ranks;
        cfg.elems_per_rank = elems_per_rank;
        cfg.hybrid_host_fraction = Some(plan.host_fraction); // reuse the calibration
        for (si, sorter) in [Sorter::Hybrid, Sorter::ThrustRadix].into_iter().enumerate() {
            cfg.sorter = sorter;
            // Pass the runtime through so HY ranks use the same engine
            // the calibration measured (artifacts when present).
            let out = run_distributed_sort::<i32>(&cfg, rt.clone())?;
            weak[si].push(ranks as f64, out.record.throughput_bps());
        }
    }
    println!(
        "{}",
        render_series_table(
            "Fig 6b — weak scaling, hybrid vs vendor-radix ranks",
            "ranks",
            "GB/s (simulated)",
            &weak
        )
    );
    dump_csv("fig6_cosort_weak", &weak);
    Ok(())
}
