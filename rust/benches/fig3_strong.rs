//! Bench: paper Fig 3 — strong scaling: fixed total divided over ranks
//! (16 GB in the paper; default 64 MB here, override AK_FIG3_TOTAL_BYTES).

use accelkern::cfg::RunConfig;
use accelkern::dtype::ElemType;
use accelkern::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let base = RunConfig::default();
    let rt = Runtime::open_default().ok();
    let total = std::env::var("AK_FIG3_TOTAL_BYTES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(64 << 20);
    let ranks = [4usize, 8, 16, 32, 64];
    accelkern::coordinator::campaign::fig3(
        &base,
        &ranks,
        total,
        &[ElemType::I32, ElemType::I64, ElemType::F32],
        &rt,
    )?;
    Ok(())
}
