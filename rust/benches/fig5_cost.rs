//! Bench: paper Fig 5 — sorting times normalised by the ×22 combined
//! capital/running/environmental GPU cost factor; prints the economic
//! crossover points (paper: GPUs only viable with GPUDirect, above ~1e6
//! elements).

use accelkern::cfg::RunConfig;
use accelkern::cost::crossover_n;
use accelkern::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let base = RunConfig::default();
    let rt = Runtime::open_default().ok();
    let ranks = 4;
    let counts = [1_000usize, 10_000, 100_000, 1_000_000, 4_000_000];
    let series = accelkern::coordinator::campaign::fig5(&base, ranks, &counts, &rt)?;

    // Crossover: normalised GG-AK vs CC-JB per dtype.
    for dt in ["Float32", "Int64"] {
        let cpu = series.iter().find(|s| s.name.starts_with("CC-JB") && s.name.contains(dt));
        let gg = series.iter().find(|s| s.name.starts_with("GG-AK") && s.name.contains(dt));
        let gc = series.iter().find(|s| s.name.starts_with("GC-AK") && s.name.contains(dt));
        if let (Some(cpu), Some(gg), Some(gc)) = (cpu, gg, gc) {
            // Series already normalised; compare directly (ratio 1.0).
            let x_gg = crossover_n(&cpu.points, &gg.points, 1.0);
            let x_gc = crossover_n(&cpu.points, &gc.points, 1.0);
            println!(
                "{dt}: GG-AK economically viable from n = {:?}; GC-AK from n = {:?} (paper: GG only, ~1e6)",
                x_gg, x_gc
            );
        }
    }
    Ok(())
}
