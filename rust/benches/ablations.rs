//! Bench: design-choice ablations (DESIGN.md §6) — SIHSort final phase,
//! radix digit width, sampling density, refinement budget.

use accelkern::cfg::RunConfig;
use accelkern::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let base = RunConfig::default();
    let rt = Runtime::open_default().ok();
    accelkern::coordinator::campaign::ablations(&base, &rt, false)
}
