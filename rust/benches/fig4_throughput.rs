//! Bench: paper Fig 4 — maximum sorting throughput per algorithm with the
//! argmax (dtype, size/rank), plus the paper's two summary ratios:
//! slowest-GPU vs CPU and mean GG vs GC speedup.

use accelkern::cfg::RunConfig;
use accelkern::dtype::ElemType;
use accelkern::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let base = RunConfig::default();
    let rt = Runtime::open_default().ok();
    let ranks = std::env::var("AK_FIG4_RANKS").ok().and_then(|s| s.parse().ok()).unwrap_or(16);
    let sizes = [1usize << 20, 4 << 20];
    let rows = accelkern::coordinator::campaign::fig4(&base, ranks, &sizes, &ElemType::ALL, &rt)?;

    // Paper summary stats.
    let cpu = rows.iter().find(|(l, _, _)| l.starts_with("CC")).map(|r| r.1).unwrap_or(0.0);
    let slowest_gpu = rows
        .iter()
        .filter(|(l, _, _)| !l.starts_with("CC"))
        .map(|r| r.1)
        .fold(f64::INFINITY, f64::min);
    let gg: Vec<f64> =
        rows.iter().filter(|(l, _, _)| l.starts_with("GG")).map(|r| r.1).collect();
    let gc: Vec<f64> =
        rows.iter().filter(|(l, _, _)| l.starts_with("GC")).map(|r| r.1).collect();
    if cpu > 0.0 {
        println!("\nslowest GPU / CPU throughput ratio: {:.2}x (paper: 7.48x)", slowest_gpu / cpu);
    }
    if !gg.is_empty() && !gc.is_empty() {
        let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
        println!("mean GG / GC speedup: {:.2}x (paper: 4.93x)", mean(&gg) / mean(&gc));
    }
    Ok(())
}
