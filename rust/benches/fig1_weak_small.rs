//! Bench: paper Fig 1 — weak scaling at small per-rank sizes (0.1 MB and
//! 10 MB per rank in the paper; scaled by default, override with env
//! AK_FIG1_SMALL / AK_FIG1_LARGE element counts).

use accelkern::cfg::RunConfig;
use accelkern::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let base = RunConfig::default();
    let rt = Runtime::open_default().ok();
    if rt.is_none() {
        eprintln!("warn: no artifacts; AK rows use the host fallback");
    }
    // Paper panel (a): 0.1 MB/rank = 25k Int32; panel (b): 10 MB/rank = 2.5M.
    let small = env_usize("AK_FIG1_SMALL", 25_000);
    let large = env_usize("AK_FIG1_LARGE", 500_000); // scaled from 2.5M
    let ranks = [1usize, 2, 4, 8, 16];
    accelkern::coordinator::campaign::fig1(&base, &ranks, small, large, &rt)?;
    Ok(())
}

fn env_usize(k: &str, d: usize) -> usize {
    std::env::var(k).ok().and_then(|s| s.parse().ok()).unwrap_or(d)
}
