# Top-level convenience targets (see README.md).

.PHONY: artifacts build test test-faults doc bench-smoke bench-sort bench-stream bench-cluster-stream clean-artifacts

# AOT-lower the L1/L2 Pallas/JAX catalog to artifacts/ (requires jax).
artifacts:
	cd python && python3 -m compile.aot --out-dir ../artifacts

build:
	cargo build --release

test:
	cargo test -q

# Fault-injection matrices. Crash/resume (DESIGN.md §15): kill the
# external and cluster sorts at every phase/pass boundary (error and
# panic modes), resume from the manifests, assert bitwise-identical
# output and zero leaked spill files. Link faults (DESIGN.md §16):
# flaky/partitioned links and killed or stalled ranks through the
# bounded fallible fabric — retries, watchdog, and in-process restarts
# must recover to the bitwise single-node answer.
test-faults:
	cargo test -q -p accelkern --test crash_resume
	cargo test -q -p accelkern --test fault_recovery

# Docs with warnings promoted to errors (the CI gate): broken intra-doc
# links on the Session/Launch surface fail the build.
doc:
	RUSTDOCFLAGS="-D warnings" cargo doc --workspace --no-deps

# One quick Criterion-style smoke bench (the in-repo harness).
bench-smoke:
	AK_FIG6_QUICK=1 cargo bench -p accelkern --bench fig6_cosort

# Host sort engine throughput sweep -> BENCH_sort.json (DESIGN.md §11).
# The run is also a correctness gate: any cross-engine divergence exits
# non-zero. Drop --quick for the full dtype grid at n = 2^22.
bench-sort: build
	cargo run --release --bin akbench -- bench-sort --quick

# Out-of-core pipeline sweep -> BENCH_stream.json (DESIGN.md §13):
# external sort of datasets 8x larger than the memory budget, verified
# bitwise against the in-memory sort (divergence exits non-zero). Drop
# --quick for the full dtype grid and the 16x ratio.
bench-stream: build
	cargo run --release --bin akbench -- bench-stream --quick

# Multi-node x out-of-core sweep -> BENCH_cluster_stream.json (DESIGN.md
# §14): SIHSort with the external rank-local sorter, each configuration
# verified bitwise against one single-node Session::sort (divergence
# exits non-zero). Drop --quick for ranks {2,4,8} x ratios {8,16} x the
# full dtype grid.
bench-cluster-stream: build
	cargo run --release --bin akbench -- bench-cluster-stream --quick

clean-artifacts:
	rm -rf artifacts
