# Top-level convenience targets (see README.md).

.PHONY: artifacts build test test-faults lint lint-fix sanitize sanitize-thread sanitize-address doc bench-smoke bench-sort bench-stream bench-records bench-cluster-stream trace-demo clean-artifacts

# AOT-lower the L1/L2 Pallas/JAX catalog to artifacts/ (requires jax).
artifacts:
	cd python && python3 -m compile.aot --out-dir ../artifacts

build:
	cargo build --release

test:
	cargo test -q

# Fault-injection matrices. Crash/resume (DESIGN.md §15): kill the
# external and cluster sorts at every phase/pass boundary (error and
# panic modes), resume from the manifests, assert bitwise-identical
# output and zero leaked spill files. Link faults (DESIGN.md §16):
# flaky/partitioned links and killed or stalled ranks through the
# bounded fallible fabric — retries, watchdog, and in-process restarts
# must recover to the bitwise single-node answer.
test-faults:
	cargo test -q -p accelkern --test crash_resume
	cargo test -q -p accelkern --test fault_recovery

# Repo-specific static analysis (DESIGN.md §17): unwrap/expect hygiene
# on the fallible comm/stream/mpisort paths, SAFETY comments on every
# unsafe block, the fail-point registry cross-check (source literals vs
# util::failpoint::SITES vs the crash_resume kill matrix), collective
# wire-tag minting, checked arithmetic in stream budget math, span
# coverage of fail-point-bearing stream/mpisort modules (DESIGN.md
# §18), and the DESIGN.md §15 site-table drift check. Zero findings is
# a CI gate; the JSON report is uploaded as a CI artifact.
lint:
	cargo run -q -p aklint -- --report aklint-report.json

# Regenerate the DESIGN.md §15 site table from util::failpoint::SITES.
lint-fix:
	cargo run -q -p aklint -- --fix-design

# Sanitizer matrix (DESIGN.md §17). `make sanitize` runs Miri over the
# unsafe hot modules (session RawScratch pool, baselines::radix
# SendPtr scatter, comm::wire, stream::codec) — the modules whose
# `unsafe` the SAFETY comments argue about. The thread/address targets
# run the full suite under TSan/ASan; all three need a nightly
# toolchain and run in the scheduled CI job with the checked-in
# suppression file.
sanitize:
	cargo +nightly miri test -q -p accelkern --lib -- \
		session:: baselines::radix:: comm::wire:: stream::codec::

sanitize-thread:
	TSAN_OPTIONS="suppressions=$(CURDIR)/ci/sanitizer-suppressions.txt" \
	RUSTFLAGS="-Z sanitizer=thread" \
	cargo +nightly test -q -p accelkern --lib --tests \
		--target x86_64-unknown-linux-gnu

sanitize-address:
	ASAN_OPTIONS="detect_odr_violation=1" \
	RUSTFLAGS="-Z sanitizer=address" \
	cargo +nightly test -q -p accelkern --lib --tests \
		--target x86_64-unknown-linux-gnu

# Docs with warnings promoted to errors (the CI gate): broken intra-doc
# links on the Session/Launch surface fail the build.
doc:
	RUSTDOCFLAGS="-D warnings" cargo doc --workspace --no-deps

# One quick Criterion-style smoke bench (the in-repo harness).
bench-smoke:
	AK_FIG6_QUICK=1 cargo bench -p accelkern --bench fig6_cosort

# Host sort engine throughput sweep -> BENCH_sort.json (DESIGN.md §11).
# The run is also a correctness gate: any cross-engine divergence exits
# non-zero. Drop --quick for the full dtype grid at n = 2^22.
bench-sort: build
	cargo run --release --bin akbench -- bench-sort --quick

# Out-of-core pipeline sweep -> BENCH_stream.json (DESIGN.md §13):
# external sort of datasets 8x larger than the memory budget, verified
# bitwise against the in-memory sort (divergence exits non-zero). Drop
# --quick for the full dtype grid and the 16x ratio.
bench-stream: build
	cargo run --release --bin akbench -- bench-stream --quick

# Record-stream (dataset engine) sweep -> BENCH_records.json (DESIGN.md
# §19): sort-by-key across payload widths, sortperm, group-reduce,
# distinct and merge-join at 8x dataset:budget, each verified (key image
# + payload bits) against an in-memory reference (divergence exits
# non-zero). Drop --quick for the 16x ratio and full sampling.
bench-records: build
	cargo run --release --bin akbench -- bench-records --quick

# Multi-node x out-of-core sweep -> BENCH_cluster_stream.json (DESIGN.md
# §14): SIHSort with the external rank-local sorter, each configuration
# verified bitwise against one single-node Session::sort (divergence
# exits non-zero). Drop --quick for ranks {2,4,8} x ratios {8,16} x the
# full dtype grid.
bench-cluster-stream: build
	cargo run --release --bin akbench -- bench-cluster-stream --quick

# Perfetto trace demo (DESIGN.md §18): a 4-rank faulted cluster-stream
# sort (external rank-local sorter, two dropped deliveries on link 0->1
# plus rank 1 killed once mid-exchange) with tracing armed. The kill
# guarantees at least one in-process driver restart, so the timeline
# shows a recovery instant next to the fault markers. Writes
# target/trace.json — load it at https://ui.perfetto.dev — and prints
# the per-track phase summary table.
trace-demo: build
	cargo run --release --bin akbench -- sort --ranks 4 \
		--local-sorter external --elems-per-rank 32768 \
		--faults "drop:0:1:2, kill:1:1:exchange" --max-restarts 2 \
		--recv-timeout 120 \
		--trace-out target/trace.json --trace-summary

clean-artifacts:
	rm -rf artifacts
