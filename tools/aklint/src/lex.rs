//! Comment/string-aware scrubbing of Rust source.
//!
//! `aklint` deliberately avoids a full parser (no `syn` in the offline
//! build): every rule it enforces is lexical — tokens, string literals,
//! comments — so all it needs is a scrub pass that separates the three
//! channels without ever confusing one for another. Line numbers are
//! preserved exactly so findings point at real source lines.

/// One file split into per-line *code* and *comment* channels, plus the
/// string literals in source order.
pub struct FileScan {
    /// Code with comments and string/char-literal contents blanked to
    /// spaces, split by line. Token positions are preserved.
    pub code: Vec<String>,
    /// Comment text per line (`//` and `/* */` alike, doc or not). A
    /// block comment spanning lines contributes to each line it covers.
    pub comment: Vec<String>,
    /// String literals as `(1-based line, value)`.
    pub strings: Vec<(usize, String)>,
}

impl FileScan {
    /// Number of lines in the file.
    pub fn lines(&self) -> usize {
        self.code.len()
    }
}

fn is_ident_byte(c: u8) -> bool {
    c.is_ascii_alphanumeric() || c == b'_'
}

/// Byte at `i`, or NUL past the end.
fn at(b: &[u8], i: usize) -> u8 {
    b.get(i).copied().unwrap_or(0)
}

/// Scrub `src` into its code/comment/string channels.
pub fn scan(src: &str) -> FileScan {
    let b = src.as_bytes();
    let mut code = String::with_capacity(src.len());
    let mut chunks: Vec<(usize, String)> = Vec::new();
    let mut strings: Vec<(usize, String)> = Vec::new();
    let mut line = 1usize;
    let mut i = 0usize;
    while i < b.len() {
        let c = b[i];
        let prev_ident = i > 0 && is_ident_byte(b[i - 1]);
        if c == b'/' && at(b, i + 1) == b'/' {
            let start = i;
            while i < b.len() && b[i] != b'\n' {
                i += 1;
            }
            chunks.push((line, src[start..i].to_string()));
            code.push_str(&" ".repeat(i - start));
        } else if c == b'/' && at(b, i + 1) == b'*' {
            let (start, start_line) = (i, line);
            let mut depth = 1u32;
            i += 2;
            while i < b.len() && depth > 0 {
                if b[i] == b'/' && at(b, i + 1) == b'*' {
                    depth += 1;
                    i += 2;
                } else if b[i] == b'*' && at(b, i + 1) == b'/' {
                    depth -= 1;
                    i += 2;
                } else {
                    if b[i] == b'\n' {
                        line += 1;
                    }
                    i += 1;
                }
            }
            chunks.push((start_line, src[start..i].to_string()));
            for &ch in &b[start..i] {
                code.push(if ch == b'\n' { '\n' } else { ' ' });
            }
        } else if c == b'"' {
            i = eat_quoted(b, i, &mut line, &mut code, &mut strings);
        } else if c == b'r' && !prev_ident && raw_open(b, i + 1).is_some() {
            i = eat_raw(b, i, i + 1, &mut line, &mut code, &mut strings);
        } else if c == b'b' && !prev_ident && at(b, i + 1) == b'"' {
            code.push(' ');
            i = eat_quoted(b, i + 1, &mut line, &mut code, &mut strings);
        } else if c == b'b' && !prev_ident && at(b, i + 1) == b'r' {
            if raw_open(b, i + 2).is_some() {
                i = eat_raw(b, i, i + 2, &mut line, &mut code, &mut strings);
            } else {
                code.push('b');
                i += 1;
            }
        } else if c == b'\'' && !prev_ident {
            i = eat_char_or_lifetime(b, i, &mut code);
        } else if c == b'\n' {
            line += 1;
            code.push('\n');
            i += 1;
        } else {
            code.push(c as char);
            i += 1;
        }
    }

    let code: Vec<String> = code.split('\n').map(|l| l.to_string()).collect();
    let mut comment = vec![String::new(); code.len()];
    for (start_line, text) in chunks {
        for (off, part) in text.split('\n').enumerate() {
            let idx = start_line - 1 + off;
            if idx < comment.len() {
                if !comment[idx].is_empty() {
                    comment[idx].push(' ');
                }
                comment[idx].push_str(part);
            }
        }
    }
    FileScan { code, comment, strings }
}

/// If `b[from..]` starts a raw-string opener (`#*"`), return the index
/// of the opening `"`; the hash count is `quote - from`.
fn raw_open(b: &[u8], from: usize) -> Option<usize> {
    let mut j = from;
    while j < b.len() && b[j] == b'#' {
        j += 1;
    }
    (j < b.len() && b[j] == b'"').then_some(j)
}

/// Does `b[i] == '"'` close a raw string with `n_hash` hashes?
fn raw_closes(b: &[u8], i: usize, n_hash: usize) -> bool {
    b.len() - i > n_hash && b[i + 1..=i + n_hash].iter().all(|&h| h == b'#')
}

/// Consume a normal (escaped) string literal, `b[open] == '"'`.
/// Returns the index just past the closing quote.
fn eat_quoted(
    b: &[u8],
    open: usize,
    line: &mut usize,
    code: &mut String,
    strings: &mut Vec<(usize, String)>,
) -> usize {
    let start_line = *line;
    let mut val = String::new();
    let mut i = open + 1;
    code.push('"');
    while i < b.len() {
        match b[i] {
            b'\\' if i + 1 < b.len() => {
                match b[i + 1] {
                    b'"' => val.push('"'),
                    b'\\' => val.push('\\'),
                    b'\n' => *line += 1,
                    other => {
                        val.push('\\');
                        val.push(other as char);
                    }
                }
                code.push_str("  ");
                i += 2;
            }
            b'"' => {
                code.push('"');
                strings.push((start_line, val));
                return i + 1;
            }
            b'\n' => {
                *line += 1;
                val.push('\n');
                code.push('\n');
                i += 1;
            }
            other => {
                val.push(other as char);
                code.push(' ');
                i += 1;
            }
        }
    }
    strings.push((start_line, val));
    i
}

/// Consume a raw string whose opening hashes start at `hashes` (the
/// `r`/`br` prefix begins at `prefix`). Returns the index past the end.
fn eat_raw(
    b: &[u8],
    prefix: usize,
    hashes: usize,
    line: &mut usize,
    code: &mut String,
    strings: &mut Vec<(usize, String)>,
) -> usize {
    let quote = match raw_open(b, hashes) {
        Some(q) => q,
        None => return prefix + 1,
    };
    let n_hash = quote - hashes;
    let start_line = *line;
    for _ in prefix..=quote {
        code.push(' ');
    }
    let mut i = quote + 1;
    let body_start = i;
    while i < b.len() {
        if b[i] == b'"' && raw_closes(b, i, n_hash) {
            let body = String::from_utf8_lossy(&b[body_start..i]).into_owned();
            strings.push((start_line, body));
            for _ in 0..=n_hash {
                code.push(' ');
            }
            return i + 1 + n_hash;
        }
        if b[i] == b'\n' {
            *line += 1;
            code.push('\n');
        } else {
            code.push(' ');
        }
        i += 1;
    }
    let body = String::from_utf8_lossy(&b[body_start..i]).into_owned();
    strings.push((start_line, body));
    i
}

/// Consume either a char literal (`'a'`, `'\n'`) — blanked — or a
/// lifetime (`'a`), which stays in the code channel.
fn eat_char_or_lifetime(b: &[u8], open: usize, code: &mut String) -> usize {
    let close = if at(b, open + 1) == b'\\' {
        let mut j = open + 3;
        // Skip the escaped payload (covers \', \n, \x41, \u{...}).
        while j < b.len() && b[j] != b'\'' && b[j] != b'\n' {
            j += 1;
        }
        (j < b.len() && b[j] == b'\'').then_some(j)
    } else if open + 2 < b.len() && b[open + 2] == b'\'' {
        Some(open + 2)
    } else {
        None
    };
    match close {
        Some(end) => {
            for _ in open..=end {
                code.push(' ');
            }
            end + 1
        }
        None => {
            code.push('\'');
            open + 1
        }
    }
}

/// Per-line mask of `#[cfg(test)]`-gated blocks (the `mod tests` at the
/// bottom of each module). Rules that only govern production code skip
/// masked lines.
pub fn test_mod_mask(scan: &FileScan) -> Vec<bool> {
    let n = scan.lines();
    let mut mask = vec![false; n];
    let mut l = 0usize;
    while l < n {
        if !scan.code[l].contains("#[cfg(test)]") {
            l += 1;
            continue;
        }
        // Find the gated item's opening brace (within a few lines).
        let mut open = None;
        for k in l..n.min(l + 6) {
            if scan.code[k].contains('{') {
                open = Some(k);
                break;
            }
        }
        let Some(open) = open else {
            l += 1;
            continue;
        };
        let mut depth = 0i64;
        let mut k = open;
        while k < n {
            for ch in scan.code[k].chars() {
                match ch {
                    '{' => depth += 1,
                    '}' => depth -= 1,
                    _ => {}
                }
            }
            mask[k] = true;
            if depth <= 0 {
                break;
            }
            k += 1;
        }
        for m in mask.iter_mut().take(open).skip(l) {
            *m = true;
        }
        l = k + 1;
    }
    mask
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn comments_and_strings_leave_the_code_channel() {
        let s = scan("let x = \"a // not a comment\"; // real\nlet y = 2; /* block */\n");
        assert!(!s.code[0].contains("not a comment"));
        assert!(!s.code[0].contains("real"));
        assert!(s.comment[0].contains("real"));
        assert_eq!(s.strings, vec![(1, "a // not a comment".to_string())]);
        assert!(s.comment[1].contains("block"));
        assert!(s.code[1].contains("let y = 2;"));
    }

    #[test]
    fn raw_strings_and_escapes() {
        let s = scan("let a = r#\"quote \" inside\"#;\nlet b = \"esc \\\" done\";\n");
        assert_eq!(s.strings[0], (1, "quote \" inside".to_string()));
        assert_eq!(s.strings[1], (2, "esc \" done".to_string()));
        assert!(!s.code[0].contains("inside"));
    }

    #[test]
    fn char_literals_are_blanked_but_lifetimes_survive() {
        let s = scan("fn f<'a>(x: &'a str) { let c = '\\''; let d = 'z'; }\n");
        assert!(s.code[0].contains("<'a>"));
        assert!(s.code[0].contains("&'a str"));
        assert!(!s.code[0].contains("'z'"));
    }

    #[test]
    fn byte_strings_are_literals_too() {
        let s = scan("let a = b\"raw bytes\"; let n = 3;\n");
        assert_eq!(s.strings[0], (1, "raw bytes".to_string()));
        assert!(!s.code[0].contains("raw bytes"));
        assert!(s.code[0].contains("let n = 3;"));
    }

    #[test]
    fn nested_block_comments_and_multiline_spans() {
        let s = scan("a /* outer /* inner */ still */ b\nnext\n");
        assert!(s.code[0].contains('a') && s.code[0].contains('b'));
        assert!(!s.code[0].contains("still"));
        assert!(s.code[1].contains("next"));
        let s2 = scan("x /* one\ntwo */ y\n");
        assert!(s2.comment[0].contains("one"));
        assert!(s2.comment[1].contains("two"));
        assert!(s2.code[1].contains('y'));
    }

    #[test]
    fn test_mod_mask_covers_the_gated_block_only() {
        let src = "fn prod() {}\n#[cfg(test)]\nmod tests {\n    fn t() {}\n}\nfn after() {}\n";
        let s = scan(src);
        let mask = test_mod_mask(&s);
        assert_eq!(mask, vec![false, true, true, true, true, false]);
    }

    #[test]
    fn line_numbers_track_multiline_strings() {
        let s = scan("let a = \"one\ntwo\";\nlet b = \"late\";\n");
        assert_eq!(s.strings[0].0, 1);
        assert_eq!(s.strings[1], (3, "late".to_string()));
    }
}
