//! `aklint` — repo-specific static analysis for the accelkern tree.
//!
//! Run from the repository root (`make lint`):
//!
//! ```text
//! aklint [--root DIR] [--report FILE.json] [--fix-design]
//! ```
//!
//! Scans every `.rs` file under `rust/src` with a comment/string-aware
//! lexical pass ([`lex`]) and applies the six rules in [`rules`]
//! (unwrap/expect hygiene, SAFETY comments, the fail-point registry
//! cross-check, collective-tag minting, checked arithmetic regions,
//! span coverage of fail-point modules), plus the DESIGN.md §15
//! site-table drift check ([`design`]). Exits
//! non-zero when any finding survives; `--report` additionally writes
//! the findings as JSON (the CI artifact).

mod design;
mod lex;
mod rules;

use std::fs;
use std::path::{Path, PathBuf};
use std::process::ExitCode;

use rules::{Finding, SourceFile};

fn main() -> ExitCode {
    let mut root = PathBuf::from(".");
    let mut report: Option<PathBuf> = None;
    let mut fix_design = false;
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--root" => match args.next() {
                Some(v) => root = PathBuf::from(v),
                None => return usage("--root needs a value"),
            },
            "--report" => match args.next() {
                Some(v) => report = Some(PathBuf::from(v)),
                None => return usage("--report needs a value"),
            },
            "--fix-design" => fix_design = true,
            other => return usage(&format!("unknown argument `{other}`")),
        }
    }

    let (findings, scanned) = match lint_repo(&root, fix_design) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("aklint: {e}");
            return ExitCode::from(2);
        }
    };
    if let Some(path) = report {
        if let Err(e) = fs::write(&path, report_json(&findings)) {
            eprintln!("aklint: cannot write {}: {e}", path.display());
            return ExitCode::from(2);
        }
    }
    for f in &findings {
        println!("{}:{}: [{}] {}", f.file, f.line, f.rule, f.msg);
    }
    if findings.is_empty() {
        println!("aklint: clean ({scanned} files scanned)");
        ExitCode::SUCCESS
    } else {
        eprintln!("aklint: {} finding(s)", findings.len());
        ExitCode::FAILURE
    }
}

fn usage(err: &str) -> ExitCode {
    eprintln!("aklint: {err}");
    eprintln!("usage: aklint [--root DIR] [--report FILE.json] [--fix-design]");
    ExitCode::from(2)
}

/// Scan the tree under `root` and run every rule. Returns the sorted
/// findings and the number of files scanned.
fn lint_repo(root: &Path, fix_design: bool) -> Result<(Vec<Finding>, usize), String> {
    let src_root = root.join("rust").join("src");
    let mut paths = Vec::new();
    walk(&src_root, &mut paths)?;
    paths.sort();

    let mut files = Vec::new();
    for p in &paths {
        let text =
            fs::read_to_string(p).map_err(|e| format!("cannot read {}: {e}", p.display()))?;
        let scan = lex::scan(&text);
        let mask = lex::test_mod_mask(&scan);
        files.push(SourceFile { path: rel_path(root, p), scan, mask });
    }

    let crash_path = root.join("rust").join("tests").join("crash_resume.rs");
    let crash = match fs::read_to_string(&crash_path) {
        Ok(t) => Some(lex::scan(&t)),
        Err(e) => return Err(format!("cannot read {}: {e}", crash_path.display())),
    };

    let mut findings = rules::run_all(&files, crash.as_ref());

    let design_path = root.join("DESIGN.md");
    let text = fs::read_to_string(&design_path)
        .map_err(|e| format!("cannot read {}: {e}", design_path.display()))?;
    if fix_design {
        match design::fix(&text) {
            Ok(Some(new)) => fs::write(&design_path, new)
                .map_err(|e| format!("cannot write {}: {e}", design_path.display()))?,
            Ok(None) => {}
            Err(msg) => findings.push(design_finding(msg)),
        }
    } else if let Err(msg) = design::check(&text) {
        findings.push(design_finding(msg));
    }

    findings.sort_by(|a, b| (&a.file, a.line, a.rule).cmp(&(&b.file, b.line, b.rule)));
    Ok((findings, files.len()))
}

fn design_finding(msg: String) -> Finding {
    Finding { rule: "design", file: "DESIGN.md".to_string(), line: 1, msg }
}

/// Collect `.rs` files under `dir`, recursively.
fn walk(dir: &Path, out: &mut Vec<PathBuf>) -> Result<(), String> {
    let entries =
        fs::read_dir(dir).map_err(|e| format!("cannot read dir {}: {e}", dir.display()))?;
    for entry in entries {
        let entry = entry.map_err(|e| format!("walk {}: {e}", dir.display()))?;
        let path = entry.path();
        if path.is_dir() {
            walk(&path, out)?;
        } else if path.extension().is_some_and(|x| x == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// Repo-relative path with forward slashes (what the rules match on).
fn rel_path(root: &Path, p: &Path) -> String {
    let rel = p.strip_prefix(root).unwrap_or(p);
    let parts: Vec<&str> = rel.iter().filter_map(|c| c.to_str()).collect();
    parts.join("/")
}

/// Hand-rolled JSON report (serde is unavailable offline).
fn report_json(findings: &[Finding]) -> String {
    let mut out = String::from("{\n  \"count\": ");
    out.push_str(&findings.len().to_string());
    out.push_str(",\n  \"findings\": [");
    for (i, f) in findings.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str("\n    {\"rule\": ");
        out.push_str(&json_str(f.rule));
        out.push_str(", \"file\": ");
        out.push_str(&json_str(&f.file));
        out.push_str(", \"line\": ");
        out.push_str(&f.line.to_string());
        out.push_str(", \"msg\": ");
        out.push_str(&json_str(&f.msg));
        out.push('}');
    }
    if !findings.is_empty() {
        out.push_str("\n  ");
    }
    out.push_str("]\n}\n");
    out
}

fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The tree this binary ships in must itself be lint-clean: running
    /// the full rule set (including the DESIGN.md site-table check)
    /// over the real repository is the strongest regression test the
    /// linter has — any scanner false positive shows up right here.
    #[test]
    fn the_repo_is_lint_clean() {
        let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("..").join("..");
        let (findings, scanned) = lint_repo(&root, false).expect("repo scan succeeds");
        let rendered: Vec<String> = findings
            .iter()
            .map(|f| format!("{}:{}: [{}] {}", f.file, f.line, f.rule, f.msg))
            .collect();
        assert!(findings.is_empty(), "aklint findings:\n{}", rendered.join("\n"));
        assert!(scanned > 40, "suspiciously few files scanned: {scanned}");
    }

    #[test]
    fn report_json_escapes_and_counts() {
        let findings = vec![Finding {
            rule: "unwrap",
            file: "a\\b.rs".to_string(),
            line: 3,
            msg: "say \"no\"".to_string(),
        }];
        let json = report_json(&findings);
        assert!(json.contains("\"count\": 1"));
        assert!(json.contains("a\\\\b.rs"));
        assert!(json.contains("say \\\"no\\\""));
        let empty = report_json(&[]);
        assert!(empty.contains("\"count\": 0"));
        assert!(empty.contains("\"findings\": []"));
    }
}
