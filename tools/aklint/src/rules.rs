//! The aklint rule set (DESIGN.md §17).
//!
//! Six lexical rules over `rust/src`:
//!
//! 1. **unwrap** — no `.unwrap()` / `.expect(` on production
//!    `comm/` / `stream/` / `mpisort/` paths; `// aklint: allow(unwrap)`
//!    with a justification exempts a site, `#[cfg(test)]` blocks are
//!    skipped.
//! 2. **safety** — every `unsafe` block or impl is preceded by a
//!    `// SAFETY:` comment (attributes and stacked `unsafe impl`s may
//!    sit between the comment and the keyword).
//! 3. **failpoint** — every `failpoint::check("name")` literal resolves
//!    to exactly one entry of the central `util::failpoint::SITES`
//!    registry, in the registered module; stale registry entries and
//!    `CrashResume` sites missing from the `tests/crash_resume.rs` kill
//!    matrix are findings.
//! 4. **tag** — the collective tag bit (`1 << 63`) is only minted by
//!    the fabric's lockstep allocator (`Endpoint::collective_tag`),
//!    never hand-built, so collective tags stay unique per endpoint.
//! 5. **checked-arith** — inside `// aklint: begin(checked-arith)`
//!    regions (budget/offset derivations in `stream/`), bare binary
//!    `+ - * / %` are findings; use `checked_*` / `saturating_*`.
//! 6. **span** — any `stream/` / `mpisort/` module that carries
//!    fail-point call sites is on a crash/fault-injected path, so it
//!    must also carry tracing (`obs::span` / `obs::span1` /
//!    `obs::phase`) in non-test code: a faulted run that leaves no
//!    trace of where it was is undebuggable (DESIGN.md §18).

use accelkern::util::failpoint::{SiteSuite, SITES};
use std::collections::BTreeMap;

use crate::lex::FileScan;

/// One lint finding, pointing at a repo-relative file and 1-based line.
pub struct Finding {
    /// Short rule identifier (`unwrap`, `safety`, ...).
    pub rule: &'static str,
    /// Repo-relative path with forward slashes.
    pub file: String,
    /// 1-based line number.
    pub line: usize,
    /// Human-readable description of the violation.
    pub msg: String,
}

impl Finding {
    fn new(rule: &'static str, file: &str, line: usize, msg: String) -> Finding {
        Finding { rule, file: file.to_string(), line, msg }
    }
}

/// A scrubbed source file plus its test-block mask.
pub struct SourceFile {
    /// Repo-relative path with forward slashes.
    pub path: String,
    /// Scrubbed channels.
    pub scan: FileScan,
    /// `true` for lines inside `#[cfg(test)]` blocks.
    pub mask: Vec<bool>,
}

const PROD_DIRS: [&str; 3] = ["rust/src/comm/", "rust/src/stream/", "rust/src/mpisort/"];

fn in_prod_dirs(path: &str) -> bool {
    PROD_DIRS.iter().any(|d| path.starts_with(d))
}

fn is_ident(c: u8) -> bool {
    c.is_ascii_alphanumeric() || c == b'_'
}

/// Find `w` in `s` as a whole word (identifier boundaries on both sides).
fn has_word(s: &str, w: &str) -> bool {
    let b = s.as_bytes();
    let mut from = 0;
    while let Some(p) = s[from..].find(w) {
        let start = from + p;
        let end = start + w.len();
        let pre = start == 0 || !is_ident(b[start - 1]);
        let post = end >= b.len() || !is_ident(b[end]);
        if pre && post {
            return true;
        }
        from = end;
    }
    false
}

/// Run every rule over the scanned tree. `crash_resume` is the scrubbed
/// `rust/tests/crash_resume.rs` (kill-matrix cross-check); `None` skips
/// that check.
pub fn run_all(files: &[SourceFile], crash_resume: Option<&FileScan>) -> Vec<Finding> {
    let mut out = Vec::new();
    for f in files {
        rule_unwrap(f, &mut out);
        rule_safety(f, &mut out);
        rule_tag(f, &mut out);
        rule_checked_arith(f, &mut out);
        rule_span(f, &mut out);
    }
    rule_failpoint(files, crash_resume, &mut out);
    out.sort_by(|a, b| (&a.file, a.line, a.rule).cmp(&(&b.file, b.line, b.rule)));
    out
}

/// Rule 1: `.unwrap()` / `.expect(` on production comm/stream/mpisort
/// paths.
fn rule_unwrap(f: &SourceFile, out: &mut Vec<Finding>) {
    if !in_prod_dirs(&f.path) {
        return;
    }
    for (idx, line) in f.scan.code.iter().enumerate() {
        if f.mask[idx] {
            continue;
        }
        for pat in [".unwrap()", ".expect("] {
            if !line.contains(pat) {
                continue;
            }
            let lo = idx.saturating_sub(3);
            let allowed = (lo..=idx).any(|j| f.scan.comment[j].contains("aklint: allow(unwrap)"));
            if allowed {
                continue;
            }
            out.push(Finding::new(
                "unwrap",
                &f.path,
                idx + 1,
                format!(
                    "`{pat}` on a production comm/stream/mpisort path — return a typed \
                     error, or annotate `// aklint: allow(unwrap)` with a justification"
                ),
            ));
        }
    }
}

/// Rule 2: `unsafe` needs a preceding `// SAFETY:` comment.
fn rule_safety(f: &SourceFile, out: &mut Vec<Finding>) {
    for idx in 0..f.scan.lines() {
        if !has_word(&f.scan.code[idx], "unsafe") {
            continue;
        }
        if !safety_covered(f, idx) {
            out.push(Finding::new(
                "safety",
                &f.path,
                idx + 1,
                "`unsafe` without a preceding `// SAFETY:` comment stating the invariant"
                    .to_string(),
            ));
        }
    }
}

fn safety_covered(f: &SourceFile, idx: usize) -> bool {
    if f.scan.comment[idx].contains("SAFETY:") {
        return true;
    }
    let mut j = idx;
    while j > 0 {
        j -= 1;
        if f.scan.comment[j].contains("SAFETY:") {
            return true;
        }
        let code = f.scan.code[j].trim();
        // Pure comment lines (a continuing SAFETY paragraph) and
        // attributes sit between the comment and the keyword; stacked
        // `unsafe impl`s may share one comment.
        if code.is_empty() && !f.scan.comment[j].is_empty() {
            continue;
        }
        if code.starts_with("#[") || code.starts_with("#!") {
            continue;
        }
        if has_word(code, "unsafe") {
            continue;
        }
        return false;
    }
    false
}

/// Site-name grammar: dotted lowercase (`ext.merge.mid`). Filters out
/// incidental same-line literals (e.g. the `"send"` comparison operand
/// in the fabric's conditional check).
fn is_site_name(s: &str) -> bool {
    let ok = |c: u8| matches!(c, b'a'..=b'z' | b'0'..=b'9' | b'.' | b'-');
    s.contains('.') && s.bytes().all(ok)
}

/// Rule 3: failpoint literals ↔ SITES registry ↔ kill matrix.
fn rule_failpoint(files: &[SourceFile], crash_resume: Option<&FileScan>, out: &mut Vec<Finding>) {
    // Collect every checked site literal in production code.
    let mut checked: BTreeMap<&str, Vec<(&str, usize)>> = BTreeMap::new();
    for f in files {
        for (idx, line) in f.scan.code.iter().enumerate() {
            if f.mask[idx] || !line.contains("failpoint::check(") {
                continue;
            }
            let lineno = idx + 1;
            let lits: Vec<&str> = f
                .scan
                .strings
                .iter()
                .filter(|(l, v)| *l == lineno && is_site_name(v))
                .map(|(_, v)| v.as_str())
                .collect();
            if lits.is_empty() {
                out.push(Finding::new(
                    "failpoint",
                    &f.path,
                    lineno,
                    "failpoint::check call without a literal site name on the same line \
                     — aklint cannot register it"
                        .to_string(),
                ));
            }
            for v in lits {
                checked.entry(v).or_default().push((f.path.as_str(), lineno));
            }
        }
    }

    // Registry self-consistency: duplicate names.
    let mut seen = std::collections::BTreeSet::new();
    for s in SITES {
        if !seen.insert(s.name) {
            out.push(Finding::new(
                "failpoint",
                "rust/src/util/failpoint.rs",
                registry_line(files, s.name),
                format!("duplicate SITES registry entry `{}`", s.name),
            ));
        }
    }

    // Checked literals must be registered, in the registered module,
    // and checked at exactly one call site.
    for (name, sites) in &checked {
        let (file, line) = sites[0];
        match SITES.iter().find(|s| s.name == *name) {
            None => out.push(Finding::new(
                "failpoint",
                file,
                line,
                format!("failpoint `{name}` is not in the util::failpoint::SITES registry"),
            )),
            Some(site) => {
                for (file, line) in sites {
                    if site.module != *file {
                        out.push(Finding::new(
                            "failpoint",
                            file,
                            *line,
                            format!(
                                "failpoint `{name}` checked here but registered for module \
                                 `{}`",
                                site.module
                            ),
                        ));
                    }
                }
                if sites.len() > 1 {
                    out.push(Finding::new(
                        "failpoint",
                        file,
                        line,
                        format!(
                            "failpoint `{name}` checked at {} call sites — per-thread skip \
                             counts are ambiguous across duplicated sites",
                            sites.len()
                        ),
                    ));
                }
            }
        }
    }

    // Stale registry entries and kill-matrix coverage.
    for s in SITES {
        if !checked.contains_key(s.name) {
            out.push(Finding::new(
                "failpoint",
                "rust/src/util/failpoint.rs",
                registry_line(files, s.name),
                format!("stale SITES entry `{}`: no failpoint::check call uses it", s.name),
            ));
        }
        if let Some(cr) = crash_resume {
            let in_matrix = cr.strings.iter().any(|(_, v)| v == s.name);
            if matches!(s.suite, SiteSuite::CrashResume) && !in_matrix {
                out.push(Finding::new(
                    "failpoint",
                    "rust/tests/crash_resume.rs",
                    1,
                    format!(
                        "CrashResume site `{}` is missing from the crash_resume.rs kill \
                         matrix",
                        s.name
                    ),
                ));
            }
        }
    }
}

/// Line of `name`'s literal inside the registry file, for pointing
/// registry findings somewhere useful.
fn registry_line(files: &[SourceFile], name: &str) -> usize {
    files
        .iter()
        .find(|f| f.path.ends_with("util/failpoint.rs"))
        .and_then(|f| f.scan.strings.iter().find(|(_, v)| v == name))
        .map(|(l, _)| *l)
        .unwrap_or(1)
}

/// Rule 4: the collective tag bit is only minted inside the fabric.
fn rule_tag(f: &SourceFile, out: &mut Vec<Finding>) {
    if !in_prod_dirs(&f.path) || f.path == "rust/src/comm/fabric.rs" {
        return;
    }
    for (idx, line) in f.scan.code.iter().enumerate() {
        if f.mask[idx] {
            continue;
        }
        if line.contains("1 << 63") || line.contains("1u64 << 63") {
            out.push(Finding::new(
                "tag",
                &f.path,
                idx + 1,
                "collective tag bit minted outside comm/fabric.rs — use \
                 Endpoint::collective_tag() so tags stay unique per endpoint schedule"
                    .to_string(),
            ));
        }
    }
}

const ARITH_BEGIN: &str = "aklint: begin(checked-arith)";
const ARITH_END: &str = "aklint: end(checked-arith)";

/// Rule 5: bare arithmetic inside `checked-arith` regions of `stream/`.
fn rule_checked_arith(f: &SourceFile, out: &mut Vec<Finding>) {
    if !f.path.starts_with("rust/src/stream/") {
        return;
    }
    let mut open: Option<usize> = None;
    for idx in 0..f.scan.lines() {
        let com = &f.scan.comment[idx];
        if com.contains(ARITH_BEGIN) {
            if open.is_some() {
                out.push(Finding::new(
                    "checked-arith",
                    &f.path,
                    idx + 1,
                    "nested checked-arith begin marker".to_string(),
                ));
            }
            open = Some(idx);
            continue;
        }
        if com.contains(ARITH_END) {
            if open.is_none() {
                out.push(Finding::new(
                    "checked-arith",
                    &f.path,
                    idx + 1,
                    "checked-arith end marker without a begin".to_string(),
                ));
            }
            open = None;
            continue;
        }
        if open.is_none() {
            continue;
        }
        let line = &f.scan.code[idx];
        for op in [" + ", " - ", " * ", " / ", " % "] {
            if line.contains(op) {
                out.push(Finding::new(
                    "checked-arith",
                    &f.path,
                    idx + 1,
                    format!(
                        "bare `{}` in a checked-arith region — budget/offset derivations \
                         must use checked_*/saturating_* so they clamp instead of wrapping",
                        op.trim()
                    ),
                ));
            }
        }
    }
    if let Some(idx) = open {
        out.push(Finding::new(
            "checked-arith",
            &f.path,
            idx + 1,
            "checked-arith begin marker never closed".to_string(),
        ));
    }
}

/// Markers that count as tracing instrumentation for rule 6. Substring
/// matches so both `obs::span1(` and `crate::obs::span1(` qualify, and
/// `obs::phase` covers `obs::phase(` / `obs::phase_end(`.
const SPAN_MARKERS: [&str; 3] = ["obs::span(", "obs::span1(", "obs::phase"];

/// Rule 6: fail-point-bearing stream/mpisort modules carry spans.
///
/// A module with `failpoint::check` sites is exactly the code a faulted
/// or crash-resumed run exercises; requiring at least one `obs::` span
/// or phase marker there keeps the Perfetto timeline able to say where
/// such a run died (DESIGN.md §18).
fn rule_span(f: &SourceFile, out: &mut Vec<Finding>) {
    let scoped =
        f.path.starts_with("rust/src/stream/") || f.path.starts_with("rust/src/mpisort/");
    if !scoped {
        return;
    }
    let mut first_check: Option<usize> = None;
    let mut traced = false;
    for (idx, line) in f.scan.code.iter().enumerate() {
        if f.mask[idx] {
            continue;
        }
        if first_check.is_none() && line.contains("failpoint::check(") {
            first_check = Some(idx);
        }
        if SPAN_MARKERS.iter().any(|m| line.contains(m)) {
            traced = true;
        }
    }
    if let (Some(idx), false) = (first_check, traced) {
        out.push(Finding::new(
            "span",
            &f.path,
            idx + 1,
            "module has failpoint::check sites but no obs::span/span1/phase call — \
             fault-injected paths must show up on the trace timeline (DESIGN.md §18)"
                .to_string(),
        ));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lex;

    fn file(path: &str, src: &str) -> SourceFile {
        let scan = lex::scan(src);
        let mask = lex::test_mod_mask(&scan);
        SourceFile { path: path.to_string(), scan, mask }
    }

    fn rules_of(findings: &[Finding]) -> Vec<&str> {
        findings.iter().map(|f| f.rule).collect()
    }

    #[test]
    fn unwrap_rule_scope_and_allowlist() {
        let bad = file("rust/src/comm/x.rs", "fn f() { y().unwrap(); }\n");
        let mut out = Vec::new();
        rule_unwrap(&bad, &mut out);
        assert_eq!(rules_of(&out), ["unwrap"]);

        // aklint annotation within three lines exempts the site.
        let ok = file(
            "rust/src/stream/x.rs",
            "// aklint: allow(unwrap) — infallible by construction\nfn f() { y().unwrap(); }\n",
        );
        let mut out = Vec::new();
        rule_unwrap(&ok, &mut out);
        assert!(out.is_empty());

        // Test blocks and non-production paths are out of scope.
        let src = "#[cfg(test)]\nmod tests {\n fn t() { y().expect(\"m\"); }\n}\n";
        let test_mod = file("rust/src/mpisort/x.rs", src);
        let mut out = Vec::new();
        rule_unwrap(&test_mod, &mut out);
        assert!(out.is_empty());
        let elsewhere = file("rust/src/session/mod.rs", "fn f() { y().unwrap(); }\n");
        let mut out = Vec::new();
        rule_unwrap(&elsewhere, &mut out);
        assert!(out.is_empty());

        // unwrap_or and friends never match.
        let or = file("rust/src/comm/x.rs", "fn f() { y().unwrap_or(0); }\n");
        let mut out = Vec::new();
        rule_unwrap(&or, &mut out);
        assert!(out.is_empty());
    }

    #[test]
    fn safety_rule_accepts_the_repo_idioms() {
        let naked = file("rust/src/a.rs", "fn f() { unsafe { g() } }\n");
        let mut out = Vec::new();
        rule_safety(&naked, &mut out);
        assert_eq!(rules_of(&out), ["safety"]);

        let commented = file("rust/src/a.rs", "// SAFETY: disjoint ranges.\nunsafe { g() }\n");
        let mut out = Vec::new();
        rule_safety(&commented, &mut out);
        assert!(out.is_empty());

        // Multi-line SAFETY paragraph, attribute in between, stacked impls.
        let stacked = file(
            "rust/src/a.rs",
            "// SAFETY: thread-safe per the C API;\n// mutation is behind a Mutex.\n\
             #[allow(dead_code)]\nunsafe impl Send for T {}\nunsafe impl Sync for T {}\n",
        );
        let mut out = Vec::new();
        rule_safety(&stacked, &mut out);
        assert!(out.is_empty());

        // The word in a comment or string is not the keyword.
        let in_comment = file("rust/src/a.rs", "// unsafe is discussed here\nlet x = \"unsafe\";\n");
        let mut out = Vec::new();
        rule_safety(&in_comment, &mut out);
        assert!(out.is_empty());
    }

    #[test]
    fn failpoint_rule_flags_unregistered_and_module_mismatch() {
        let f = file(
            "rust/src/stream/external_sort.rs",
            "fn f() -> anyhow::Result<()> { failpoint::check(\"no.such.site\")?; Ok(()) }\n",
        );
        let mut out = Vec::new();
        rule_failpoint(&[f], None, &mut out);
        assert!(out.iter().any(|x| x.msg.contains("not in the util::failpoint::SITES")));
        // Every registered site is stale in this synthetic tree.
        let stale = out.iter().filter(|x| x.msg.contains("stale SITES entry")).count();
        assert_eq!(stale, SITES.len());

        // A registered name checked from the wrong module.
        let f = file(
            "rust/src/stream/external_sort.rs",
            "fn f() -> anyhow::Result<()> { failpoint::check(\"sih.park\")?; Ok(()) }\n",
        );
        let mut out = Vec::new();
        rule_failpoint(&[f], None, &mut out);
        assert!(out.iter().any(|x| x.msg.contains("registered for module")));
    }

    #[test]
    fn failpoint_rule_checks_the_kill_matrix() {
        // The real tree's call sites, minimally: every site checked once
        // from its registered module.
        let files: Vec<SourceFile> = SITES
            .iter()
            .map(|s| {
                let src = format!(
                    "fn f() -> anyhow::Result<()> {{ failpoint::check(\"{}\")?; Ok(()) }}\n",
                    s.name
                );
                file(s.module, &src)
            })
            .collect();
        // A kill matrix that lists every CrashResume site is clean.
        let all: String = SITES.iter().map(|s| format!("\"{}\",\n", s.name)).collect();
        let matrix = lex::scan(&all);
        let mut out = Vec::new();
        rule_failpoint(&files, Some(&matrix), &mut out);
        assert!(out.is_empty(), "{:?}", rules_of(&out));
        // Dropping one CrashResume site from the matrix is a finding.
        let partial: String = SITES
            .iter()
            .filter(|s| s.name != "ext.run")
            .map(|s| format!("\"{}\",\n", s.name))
            .collect();
        let matrix = lex::scan(&partial);
        let mut out = Vec::new();
        rule_failpoint(&files, Some(&matrix), &mut out);
        assert!(out.iter().any(|x| x.msg.contains("missing from the crash_resume.rs")));
    }

    #[test]
    fn tag_rule_confines_the_collective_bit_to_the_fabric() {
        let f = file("rust/src/mpisort/exchange.rs", "let t = (1 << 63) | seq;\n");
        let mut out = Vec::new();
        rule_tag(&f, &mut out);
        assert_eq!(rules_of(&out), ["tag"]);
        let fabric = file("rust/src/comm/fabric.rs", "let t = (1 << 63) | seq;\n");
        let mut out = Vec::new();
        rule_tag(&fabric, &mut out);
        assert!(out.is_empty());
    }

    #[test]
    fn span_rule_pairs_failpoints_with_tracing() {
        // A fail-point module with no tracing marker is a finding.
        let bare = file(
            "rust/src/stream/x.rs",
            "fn f() -> anyhow::Result<()> { failpoint::check(\"x.mid\")?; Ok(()) }\n",
        );
        let mut out = Vec::new();
        rule_span(&bare, &mut out);
        assert_eq!(rules_of(&out), ["span"]);
        assert_eq!(out[0].line, 1);

        // Any of the markers satisfies the rule, qualified paths too.
        for marker in [
            "let _s = obs::span(obs::SpanKind::Pass, \"x.pass\");",
            "let _s = crate::obs::span1(crate::obs::SpanKind::Pass, \"x.pass\", n);",
            "ep.note_phase_via(obs::phase(\"x\"));",
        ] {
            let src = format!(
                "fn f() -> anyhow::Result<()> {{ {marker} failpoint::check(\"x.mid\")?; Ok(()) }}\n"
            );
            let traced = file("rust/src/mpisort/x.rs", &src);
            let mut out = Vec::new();
            rule_span(&traced, &mut out);
            assert!(out.is_empty(), "marker `{marker}` should satisfy the rule");
        }

        // Markers inside #[cfg(test)] blocks do not count.
        let test_only = file(
            "rust/src/stream/x.rs",
            "fn f() -> anyhow::Result<()> { failpoint::check(\"x.mid\")?; Ok(()) }\n\
             #[cfg(test)]\nmod tests {\n fn t() { let _s = obs::span(k, \"t\"); }\n}\n",
        );
        let mut out = Vec::new();
        rule_span(&test_only, &mut out);
        assert_eq!(rules_of(&out), ["span"]);

        // Out-of-scope dirs and span-free modules are untouched.
        let comm = file(
            "rust/src/comm/x.rs",
            "fn f() -> anyhow::Result<()> { failpoint::check(\"x.mid\")?; Ok(()) }\n",
        );
        let mut out = Vec::new();
        rule_span(&comm, &mut out);
        assert!(out.is_empty());
        let plain = file("rust/src/stream/x.rs", "fn f() {}\n");
        let mut out = Vec::new();
        rule_span(&plain, &mut out);
        assert!(out.is_empty());
    }

    #[test]
    fn checked_arith_rule_guards_marked_regions() {
        let f = file(
            "rust/src/stream/mod.rs",
            "// aklint: begin(checked-arith)\nlet a = b.saturating_mul(2);\nlet c = b / 3;\n\
             // aklint: end(checked-arith)\nlet outside = b * 2;\n",
        );
        let mut out = Vec::new();
        rule_checked_arith(&f, &mut out);
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].line, 3);

        let unclosed = file("rust/src/stream/mod.rs", "// aklint: begin(checked-arith)\n");
        let mut out = Vec::new();
        rule_checked_arith(&unclosed, &mut out);
        assert!(out.iter().any(|x| x.msg.contains("never closed")));
    }
}
