"""Scan + reduce kernels vs oracle."""

import functools

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import reduce as kreduce

DTYPES = [jnp.int32, jnp.int64, jnp.float32, jnp.float64]


def make_array(seed, n, dtype):
    rng = np.random.default_rng(seed)
    if jnp.issubdtype(dtype, jnp.integer):
        return jnp.array(rng.integers(-10_000, 10_000, n), dtype)
    return jnp.array(rng.random(n) - 0.5, dtype)


@settings(max_examples=20, deadline=None)
@given(
    seed=st.integers(0, 2**31),
    log2n=st.integers(4, 13),
    dti=st.integers(0, 3),
    inclusive=st.booleans(),
)
def test_accumulate_add(seed, log2n, dti, inclusive):
    dtype = DTYPES[dti]
    x = make_array(seed, 1 << log2n, dtype)
    got = np.asarray(
        jax.jit(functools.partial(model.accumulate, op="add", inclusive=inclusive))(x)
    )
    xa = np.asarray(x)
    if jnp.issubdtype(dtype, jnp.integer):
        want = np.cumsum(xa, dtype=xa.dtype)
        if not inclusive:
            want = np.concatenate([[xa.dtype.type(0)], want[:-1]])
        np.testing.assert_array_equal(got, want)
    else:
        # Prefix sums cancel: error scales with sum(|x|), not the running
        # total, so compare against a float64 reference with a
        # summation-aware absolute tolerance.
        want = np.cumsum(xa.astype(np.float64))
        if not inclusive:
            want = np.concatenate([[0.0], want[:-1]])
        eps = 1e-7 if xa.dtype == np.float32 else 1e-15
        atol = eps * np.abs(xa).sum() * np.log2(max(len(xa), 2))
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=atol)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31), log2n=st.integers(4, 13), op_i=st.integers(0, 2))
def test_accumulate_min_max(seed, log2n, op_i):
    if op_i == 0:
        return  # add covered above
    op = ["add", "max", "min"][op_i]
    x = make_array(seed, 1 << log2n, jnp.int32)
    got = np.asarray(jax.jit(functools.partial(model.accumulate, op=op))(x))
    fn = np.maximum if op == "max" else np.minimum
    want = fn.accumulate(np.asarray(x))
    np.testing.assert_array_equal(got, want)


@settings(max_examples=20, deadline=None)
@given(
    seed=st.integers(0, 2**31),
    log2n=st.integers(4, 13),
    dti=st.integers(0, 3),
    op_i=st.integers(0, 2),
)
def test_reduce_ops(seed, log2n, dti, op_i):
    op = ["add", "min", "max"][op_i]
    dtype = DTYPES[dti]
    x = make_array(seed, 1 << log2n, dtype)
    got = jax.jit(functools.partial(model.reduce, op=op))(x)
    xa = np.asarray(x)
    want = {"add": xa.sum(), "min": xa.min(), "max": xa.max()}[op]
    if jnp.issubdtype(dtype, jnp.integer):
        assert int(got) == int(want)
    else:
        np.testing.assert_allclose(float(got), float(want), rtol=1e-4)


def test_reduce_partials_shape_and_sum():
    x = jnp.arange(1 << 14, dtype=jnp.int64)
    parts = np.asarray(jax.jit(model.reduce_partials)(x))
    assert parts.shape == ((1 << 14) // 1024,)
    assert parts.sum() == np.asarray(x).sum()


def test_mapreduce_maps():
    for name, f in kreduce.MAPS.items():
        x = jnp.array([-2.0, 3.0, -4.0], jnp.float32)
        parts = kreduce.reduce_tiles(
            jnp.resize(x, 1024), "add", name, tile=1024
        )
        expected = float(jnp.sum(f(jnp.resize(x, 1024))))
        np.testing.assert_allclose(float(parts[0]), expected, rtol=1e-5)


def test_output_dtypes_match_inputs():
    # Regression: under jax_enable_x64, jnp.sum/cumsum upcast i16/i32 to
    # i64 — artifact outputs must keep the input dtype or the Rust
    # runtime's typed literal reads fail.
    for dtype in DTYPES:
        x = make_array(0, 1 << 12, dtype)
        assert jax.jit(functools.partial(model.reduce, op="add"))(x).dtype == dtype
        assert jax.jit(functools.partial(model.accumulate, op="add"))(x).dtype == dtype
        assert jax.jit(model.reduce_partials)(x).dtype == dtype
        assert jax.jit(model.merge_sort)(x).dtype == dtype
