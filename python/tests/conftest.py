"""Shared pytest fixtures/utilities for the L1/L2 test suite.

x64 is enabled by `compile/__init__.py` (imported below) — the same config
the AOT path uses, so tests exercise exactly what ships to Rust.
"""

import numpy as np
import pytest

import compile  # noqa: F401  (side effect: jax_enable_x64)


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0xAC)


def assert_allclose_dtype(got, want, dtype):
    got = np.asarray(got)
    want = np.asarray(want)
    if np.issubdtype(np.dtype(dtype), np.integer):
        np.testing.assert_array_equal(got, want)
    else:
        rtol = 1e-6 if np.dtype(dtype) == np.float64 else 1e-4
        np.testing.assert_allclose(got, want, rtol=rtol, atol=1e-30)
