"""L1/L2 sorting kernels vs the pure-jnp oracle (hypothesis sweeps)."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref, sort_tile

DTYPES = [jnp.int16, jnp.int32, jnp.int64, jnp.float32, jnp.float64]


def make_array(rng_seed, n, dtype):
    rng = np.random.default_rng(rng_seed)
    if jnp.issubdtype(dtype, jnp.integer):
        info = jnp.iinfo(dtype)
        return jnp.array(
            rng.integers(int(info.min), int(info.max), n, endpoint=True), dtype
        )
    return jnp.array((rng.random(n) - 0.5) * 2e6, dtype)


@settings(max_examples=20, deadline=None)
@given(
    seed=st.integers(0, 2**31),
    log2n=st.integers(4, 12),
    dti=st.integers(0, len(DTYPES) - 1),
)
def test_merge_sort_matches_oracle(seed, log2n, dti):
    x = make_array(seed, 1 << log2n, DTYPES[dti])
    got = jax.jit(model.merge_sort)(x)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref.sort(x)))


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31), log2n=st.integers(4, 11))
def test_sortperm_is_stable_permutation(seed, log2n):
    n = 1 << log2n
    rng = np.random.default_rng(seed)
    # Duplicate-heavy keys stress the stability tie-break.
    x = jnp.array(rng.integers(-8, 8, n), jnp.int32)
    keys, perm = jax.jit(model.sortperm)(x)
    xa = np.asarray(x)
    pa = np.asarray(perm)
    assert sorted(pa.tolist()) == list(range(n)), "not a permutation"
    np.testing.assert_array_equal(xa[pa], np.sort(xa, kind="stable"))
    np.testing.assert_array_equal(np.asarray(keys), np.sort(xa))
    # Stability: equal keys keep ascending original indices.
    ka = np.asarray(keys)
    for i in range(n - 1):
        if ka[i] == ka[i + 1]:
            assert pa[i] < pa[i + 1]


def test_tile_sort_produces_alternating_runs():
    # The tile kernel must emit even tiles ascending, odd tiles
    # descending: that is its contract with the global merge stages.
    rng = np.random.default_rng(3)
    x = jnp.array(rng.integers(-1000, 1000, 4096), jnp.int32)
    out = np.asarray(sort_tile.sort_tiles(x, tile=1024))
    for t in range(4):
        run = out[1024 * t : 1024 * (t + 1)]
        if t % 2 == 0:
            assert (np.diff(run) >= 0).all(), f"tile {t} not ascending"
        else:
            assert (np.diff(run) <= 0).all(), f"tile {t} not descending"


def test_merge_sort_with_infinities_and_duplicates():
    x = jnp.array(
        [np.inf, -np.inf, 0.0, -0.0, 1.5, 1.5, -2.25, np.inf] * 128, jnp.float32
    )
    got = np.asarray(jax.jit(model.merge_sort)(x))
    np.testing.assert_array_equal(got, np.sort(np.asarray(x)))


def test_sort_pairs_carries_payloads():
    rng = np.random.default_rng(4)
    keys = jnp.array(rng.integers(-100, 100, 2048), jnp.int64)
    vals = jnp.arange(2048, dtype=jnp.int32)
    km, vm = jax.jit(model.merge_sort_pairs)(keys, vals)
    ka, va = np.asarray(km), np.asarray(vm)
    assert (np.diff(ka) >= 0).all()
    np.testing.assert_array_equal(np.asarray(keys)[va], ka)
