"""Binary search + arithmetic (RBF/LJG) kernels vs oracle."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref

DTYPES = [jnp.int16, jnp.int32, jnp.int64, jnp.float32, jnp.float64]


@settings(max_examples=20, deadline=None)
@given(
    seed=st.integers(0, 2**31),
    log2n=st.integers(4, 13),
    dti=st.integers(0, len(DTYPES) - 1),
    side=st.sampled_from(["first", "last"]),
)
def test_searchsorted_matches_numpy(seed, log2n, dti, side):
    dtype = DTYPES[dti]
    rng = np.random.default_rng(seed)
    n = 1 << log2n
    if jnp.issubdtype(dtype, jnp.integer):
        hay = jnp.sort(jnp.array(rng.integers(-50, 50, n), dtype))
        needles = jnp.array(rng.integers(-60, 60, 1024), dtype)
    else:
        hay = jnp.sort(jnp.array(rng.random(n) * 100, dtype))
        needles = jnp.array(rng.random(1024) * 120 - 10, dtype)
    fn = model.searchsorted_first if side == "first" else model.searchsorted_last
    got = np.asarray(jax.jit(fn)(hay, needles))
    want = np.searchsorted(
        np.asarray(hay), np.asarray(needles), "left" if side == "first" else "right"
    )
    np.testing.assert_array_equal(got, want)


def test_searchsorted_duplicate_blocks():
    hay = jnp.array([1, 3, 3, 3, 7] + [9] * 1019, jnp.int32)
    needles = jnp.resize(jnp.array([3, 0, 9, 10], jnp.int32), 1024)
    first = np.asarray(jax.jit(model.searchsorted_first)(hay, needles))
    last = np.asarray(jax.jit(model.searchsorted_last)(hay, needles))
    assert first[0] == 1 and last[0] == 4
    assert first[1] == 0 and last[1] == 0
    assert first[2] == 5 and last[2] == 1024
    assert first[3] == 1024


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31), log2n=st.integers(10, 14), f64=st.booleans())
def test_rbf_matches_oracle(seed, log2n, f64):
    dtype = jnp.float64 if f64 else jnp.float32
    rng = np.random.default_rng(seed)
    n = 1 << log2n
    pts = jnp.array((rng.random((3, n)) - 0.5), dtype)  # r < 0.87
    got = np.asarray(jax.jit(model.rbf)(pts))
    want = np.asarray(ref.rbf(pts))
    np.testing.assert_allclose(got, want, rtol=1e-5 if f64 else 1e-4)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31), log2n=st.integers(10, 14), f64=st.booleans())
def test_ljg_matches_oracle(seed, log2n, f64):
    dtype = jnp.float64 if f64 else jnp.float32
    rng = np.random.default_rng(seed)
    n = 1 << log2n
    p1 = jnp.array(rng.random((3, n)) * 4, dtype)
    p2 = jnp.array(rng.random((3, n)) * 4, dtype)
    consts = jnp.array([1.0, 1.0, 1.5, 3.0], dtype)
    got = np.asarray(jax.jit(model.ljg)(p1, p2, consts))
    want = np.asarray(ref.ljg(p1, p2, 1.0, 1.0, 1.5, 3.0))
    np.testing.assert_allclose(got, want, rtol=1e-5 if f64 else 2e-3, atol=1e-6)


def test_ljg_cutoff_branch_exact_zero():
    # Atoms beyond the cutoff contribute exactly 0 (branch, not decay).
    n = 1024
    p1 = jnp.zeros((3, n), jnp.float32)
    p2 = jnp.ones((3, n), jnp.float32) * 10.0
    consts = jnp.array([1.0, 1.0, 1.5, 3.0], jnp.float32)
    got = np.asarray(model.ljg(p1, p2, consts))
    assert (got == 0.0).all()


def test_predicates_any_all():
    x = jnp.linspace(0, 1, 1 << 14, dtype=jnp.float32)
    assert int(jax.jit(model.any_gt)(x, jnp.float32(0.999))) == 1
    assert int(jax.jit(model.any_gt)(x, jnp.float32(2.0))) == 0
    assert int(jax.jit(model.all_gt)(x, jnp.float32(-0.1))) == 1
    assert int(jax.jit(model.all_gt)(x, jnp.float32(0.5))) == 0
