"""AOT pipeline tests: catalog integrity, HLO text validity, 64-bit
parameter widths (the jax_enable_x64 regression), manifest consistency."""

import json
import os
import re

import pytest

from compile import aot

ART_DIR = os.path.abspath(
    os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
)


def test_catalog_names_unique_and_wellformed():
    cat = aot.build_catalog()
    assert len(cat) > 100
    for name, ent in cat.items():
        assert re.fullmatch(r"[a-z0-9_]+", name), name
        assert ent["meta"]["n"] & (ent["meta"]["n"] - 1) == 0, f"{name}: n not pow2"
        assert ent["inputs"] and ent["outputs"], name


def test_lowering_emits_64bit_params():
    # Regression: without jax_enable_x64 the i64 sort lowers with s32
    # parameters and the Rust runtime rejects the buffers.
    cat = aot.build_catalog()
    ent = cat["sort_i64_n10"]
    text = aot.to_hlo_text(ent["fn"], ent["specs"])
    assert "s64[1024]" in text, "i64 artifact lost its 64-bit width"
    ent = cat["reduce_add_f64_n14"]
    text = aot.to_hlo_text(ent["fn"], ent["specs"])
    assert "f64[16384]" in text


def test_hlo_text_is_parseable_entry_computation():
    cat = aot.build_catalog()
    ent = cat["reduce_add_f32_n14"]
    text = aot.to_hlo_text(ent["fn"], ent["specs"])
    assert text.startswith("HloModule"), text[:50]
    assert "ENTRY" in text


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ART_DIR, "manifest.json")),
    reason="run `make artifacts` first",
)
def test_manifest_matches_disk():
    with open(os.path.join(ART_DIR, "manifest.json")) as f:
        man = json.load(f)
    assert man["version"] == 1
    names = [a["name"] for a in man["artifacts"]]
    assert len(names) == len(set(names))
    for a in man["artifacts"]:
        path = os.path.join(ART_DIR, a["file"])
        assert os.path.exists(path), a["name"]
        # dtype consistency: artifact dtype appears in its input specs.
        assert any(i["dtype"] == a["dtype"] for i in a["inputs"]), a["name"]


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ART_DIR, "manifest.json")),
    reason="run `make artifacts` first",
)
def test_manifest_covers_catalog():
    with open(os.path.join(ART_DIR, "manifest.json")) as f:
        man = json.load(f)
    disk = {a["name"] for a in man["artifacts"]}
    cat = set(aot.build_catalog())
    assert cat == disk
