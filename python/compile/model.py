"""L2: the JAX compute graphs, composing the L1 Pallas kernels.

Each public function here becomes one (or more) AOT artifacts: `aot.py`
lowers `jax.jit(fn)` for every (dtype, size-class) variant to HLO text
which the Rust runtime loads via PJRT. Shapes are static — the Rust side
pads inputs to the next size class with order-preserving sentinels (sort)
or op identities (scan/reduce) and truncates outputs.

Design rule: one fused HLO module per operation — the L3 hot path performs
exactly one `execute` per primitive call (no per-stage dispatch), which is
the transpiled-artifact analog of the paper's single fused GPU kernel
launch sequence.
"""

import jax
import jax.numpy as jnp

from .kernels import reduce as kreduce
from .kernels import scan as kscan
from .kernels import searchsorted as ksearch
from .kernels import sort_tile as ksort
from .kernels.common import (DEFAULT_TILE, bitonic_merge_stages,
                             compare_exchange_pairs_reshape,
                             compare_exchange_reshape)
from .kernels.ljg import ljg as ljg_kernel
from .kernels.rbf import rbf as rbf_kernel

# ---------------------------------------------------------------------------
# Sorting


def merge_sort(x, *, tile: int = DEFAULT_TILE):
    """Full ascending sort of a power-of-two length array.

    Phase 1 (L1): bitonic tile sort — each VMEM tile sorted independently.
    Phase 2 (L2): global bitonic merge stages (k > tile) — cross-tile
    compare-exchange sweeps, each lowering to one fused gather/select HLO.
    This mirrors the paper's merge_sort: block-local sort then global
    merging, with the block size set by shared-memory (here VMEM) capacity.
    """
    n = x.shape[0]
    assert n & (n - 1) == 0, "size classes are powers of two"
    t = min(tile, n)
    v = ksort.sort_tiles(x, tile=t)
    for k, j in bitonic_merge_stages(n, t):
        v = compare_exchange_reshape(v, k, j)
    return v


def merge_sort_pairs(keys, vals, *, tile: int = DEFAULT_TILE):
    """Key-value sort; payload lanes travel with their keys. Deterministic
    under duplicate keys (payload-index tie-break), so it doubles as a
    stable sort when vals = iota."""
    n = keys.shape[0]
    assert n & (n - 1) == 0
    t = min(tile, n)
    keys, vals = ksort.sort_pairs_tiles(keys, vals, tile=t)
    for k, j in bitonic_merge_stages(n, t):
        keys, vals = compare_exchange_pairs_reshape(keys, vals, k, j)
    return keys, vals


def sortperm(x, *, tile: int = DEFAULT_TILE):
    """Index permutation sorting x (paper's sortperm): key-value sort with
    vals = iota; returns (sorted_keys, permutation)."""
    n = x.shape[0]
    perm = jnp.arange(n, dtype=jnp.int32)
    return merge_sort_pairs(x, perm, tile=tile)


# ---------------------------------------------------------------------------
# Reduction / accumulation


def reduce(x, op: str = "add", map_name: str = "identity",
           *, tile: int = DEFAULT_TILE):
    """Scalar reduction: L1 per-tile partials + L2 fold. Returns ()."""
    parts = kreduce.reduce_tiles(x, op, map_name, tile=min(tile, x.shape[0]))
    if op == "add":
        return jnp.sum(parts, dtype=x.dtype)
    if op == "max":
        return jnp.max(parts)
    if op == "min":
        return jnp.min(parts)
    raise ValueError(op)


def reduce_partials(x, op: str = "add", map_name: str = "identity",
                    *, tile: int = DEFAULT_TILE):
    """`switch_below` variant: returns the (n/tile,) per-tile partials so
    the host can finish the fold — the paper's device-sync-masking
    optimisation, exercised by `algorithms::reduce` on the Rust side."""
    return kreduce.reduce_tiles(x, op, map_name, tile=min(tile, x.shape[0]))


def accumulate(x, op: str = "add", inclusive: bool = True,
               *, tile: int = DEFAULT_TILE):
    """Prefix scan (paper's accumulate): three-phase block scan.

    Tile scans and carry application are L1 Pallas kernels; the tiny
    (n/tile,) carry scan runs as plain HLO in between. Exclusive scans
    shift the inclusive result right by one lane with the op identity.
    """
    n = x.shape[0]
    t = min(tile, n)
    tile_scans, tile_sums = kscan.scan_tiles(x, op, tile=t)
    if op == "add":
        carries = jnp.concatenate(
            [jnp.zeros((1,), x.dtype), jnp.cumsum(tile_sums, dtype=x.dtype)[:-1]])
    elif op == "max":
        run = jax.lax.cummax(tile_sums, axis=0)
        lowest = _op_identity(x.dtype, "max")
        carries = jnp.concatenate([jnp.full((1,), lowest, x.dtype), run[:-1]])
    elif op == "min":
        run = jax.lax.cummin(tile_sums, axis=0)
        highest = _op_identity(x.dtype, "min")
        carries = jnp.concatenate([jnp.full((1,), highest, x.dtype), run[:-1]])
    else:
        raise ValueError(op)
    out = kscan.add_carries(tile_scans, carries, op, tile=t)
    if inclusive:
        return out
    ident = _op_identity(x.dtype, op)
    return jnp.concatenate([jnp.full((1,), ident, x.dtype), out[:-1]])


def _op_identity(dtype, op):
    dtype = jnp.dtype(dtype)
    if op == "add":
        return jnp.array(0, dtype)
    if jnp.issubdtype(dtype, jnp.floating):
        return jnp.array(-jnp.inf if op == "max" else jnp.inf, dtype)
    info = jnp.iinfo(dtype)
    return jnp.array(info.min if op == "max" else info.max, dtype)


# ---------------------------------------------------------------------------
# Binary search & predicates


def searchsorted_first(haystack, needles, *, tile: int = DEFAULT_TILE):
    return ksearch.searchsorted(haystack, needles, "first",
                                tile=min(tile, needles.shape[0]))


def searchsorted_last(haystack, needles, *, tile: int = DEFAULT_TILE):
    return ksearch.searchsorted(haystack, needles, "last",
                                tile=min(tile, needles.shape[0]))


def any_gt(x, threshold, *, tile: int = DEFAULT_TILE):
    """True iff any element exceeds `threshold` (runtime scalar).

    The paper ships two `any` algorithms: a concurrent-write one and a
    conservative mapreduce one. One fused HLO cannot early-exit, so the
    artifact is the conservative chunk-predicate; the Rust layer supplies
    the early exit by scanning chunk by chunk (algorithms::predicates).
    Returns an i32 scalar (0/1) — PRED round-trips awkwardly through PJRT.
    """
    mask = (x > threshold).astype(jnp.int32)
    parts = kreduce.reduce_tiles(mask, "max", tile=min(tile, x.shape[0]))
    return jnp.max(parts)


def all_gt(x, threshold, *, tile: int = DEFAULT_TILE):
    mask = (x > threshold).astype(jnp.int32)
    parts = kreduce.reduce_tiles(mask, "min", tile=min(tile, x.shape[0]))
    return jnp.min(parts)


# ---------------------------------------------------------------------------
# Arithmetic benchmark kernels (Table II)


def rbf(points, *, tile: int = DEFAULT_TILE):
    """Radial Basis Function over (3, n) points -> (n,)."""
    return rbf_kernel(points, tile=min(tile, points.shape[1]))


def ljg(p1, p2, consts, *, tile: int = DEFAULT_TILE):
    """Lennard-Jones-Gauss potential over two (3, n) position arrays with
    runtime constants (4,) [eps, sigma, r0, cutoff] -> (n,)."""
    return ljg_kernel(p1, p2, consts, tile=min(tile, p1.shape[1]))
