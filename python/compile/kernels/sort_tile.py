"""Bitonic tile-sort Pallas kernels (L1 of the merge-sort pipeline).

The paper's `merge_sort` starts with each CUDA thread block sorting a tile
in shared memory. TPU adaptation: one Pallas grid step owns a `(TILE,)`
block in VMEM and runs the full bitonic network *vectorised over the whole
tile* — every compare-exchange stage is a branch-free where(min, max) over
all lanes, so there is no per-thread control flow at all. The global merge
stages (k > TILE, which need cross-tile communication) run at L2 — see
`compile.model.merge_sort` — mirroring the paper's split between
block-local sorting and global merging.

Two kernels: key-only (`sort_tiles`) and key-value (`sort_pairs_tiles`,
used by `sortperm` / `merge_sort_by_key`).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import (
    DEFAULT_TILE,
    INTERPRET,
    bitonic_stages,
    compare_exchange_pairs_reshape,
    compare_exchange_reshape,
)


def _tile_sort_kernel(x_ref, o_ref):
    v = x_ref[...]
    n = v.shape[0]
    # Gather-free reshape network (see common.compare_exchange_reshape):
    # sorts the tile ascending. Odd tiles are then *reversed* so tiles
    # alternate direction by global parity — the contract the L2 global
    # bitonic merge stages require (a reverse is a cheap strided copy;
    # per-lane xor gathers were ~20x slower under XLA-CPU interpret).
    for k, j in bitonic_stages(n):
        v = compare_exchange_reshape(v, k, j)
    pid = pl.program_id(0)
    o_ref[...] = jnp.where(pid % 2 == 0, v, v[::-1])


def _tile_sort_pairs_kernel(k_ref, v_ref, ko_ref, vo_ref):
    keys = k_ref[...]
    vals = v_ref[...]
    n = keys.shape[0]
    for k, j in bitonic_stages(n):
        keys, vals = compare_exchange_pairs_reshape(keys, vals, k, j)
    pid = pl.program_id(0)
    even = pid % 2 == 0
    ko_ref[...] = jnp.where(even, keys, keys[::-1])
    vo_ref[...] = jnp.where(even, vals, vals[::-1])


def sort_tiles(x, *, tile: int = DEFAULT_TILE):
    """Sort each `tile`-sized block of `x` ascending (blocks independent).

    `len(x)` must be a multiple of `tile` and `tile` a power of two; the
    L2 wrapper pads with the dtype's sort sentinel.
    """
    n = x.shape[0]
    assert n % tile == 0 and tile & (tile - 1) == 0
    grid = (n // tile,)
    return pl.pallas_call(
        _tile_sort_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((tile,), lambda i: (i,))],
        out_specs=pl.BlockSpec((tile,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), x.dtype),
        interpret=INTERPRET,
    )(x)


def sort_pairs_tiles(keys, vals, *, tile: int = DEFAULT_TILE):
    """Key-value variant: sorts each block of `keys` carrying `vals` along,
    with deterministic (payload-index) tie-breaking on duplicate keys."""
    n = keys.shape[0]
    assert n % tile == 0 and tile & (tile - 1) == 0
    assert vals.shape == keys.shape
    grid = (n // tile,)
    return pl.pallas_call(
        _tile_sort_pairs_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((tile,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((tile,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), keys.dtype),
            jax.ShapeDtypeStruct((n,), vals.dtype),
        ],
        interpret=INTERPRET,
    )(keys, vals)
