"""Binary-search Pallas kernel: searchsortedfirst / searchsortedlast.

The paper singles these out (std::lower_bound / upper_bound) as the
primitives missing from Kokkos/RAJA yet required by MPISort's splitter
partitioning. CUDA formulation: one thread per needle. TPU adaptation: a
`(TILE,)` needle block per grid step, the whole sorted haystack resident
in VMEM (haystack size-classes are chosen so this holds), and a
*branch-free* binary search: exactly ceil(log2(n)) where-steps vectorised
over the needle tile — no data-dependent trip counts, so the network is
identical for every lane (the GPU-friendly formulation the paper uses).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import DEFAULT_TILE, INTERPRET


def _searchsorted_kernel(side, steps):
    assert side in ("first", "last")

    def kernel(hay_ref, needles_ref, out_ref):
        hay = hay_ref[...]
        needles = needles_ref[...]
        m = needles.shape[0]
        lo = jnp.zeros((m,), jnp.int32)
        hi = jnp.full((m,), hay.shape[0], jnp.int32)
        # Branch-free: fixed `steps` iterations, each lane halves [lo, hi).
        # Lanes whose interval is already empty (lo == hi) must hold
        # position: without the `active` mask the clamped out-of-bounds
        # gather would keep pushing `lo` past n.
        for _ in range(steps):
            active = lo < hi
            mid = jnp.minimum((lo + hi) // 2, hay.shape[0] - 1)
            hv = hay[mid]
            if side == "first":
                go_right = active & (hv < needles)
            else:
                go_right = active & (hv <= needles)
            lo = jnp.where(go_right, mid + 1, lo)
            hi = jnp.where(active & ~go_right, mid, hi)
        out_ref[...] = lo

    return kernel


def searchsorted(haystack, needles, side: str = "first",
                 *, tile: int = DEFAULT_TILE):
    """Insertion indices of `needles` into sorted `haystack`.

    side="first" -> leftmost (lower_bound); side="last" -> rightmost
    (upper_bound). len(needles) % tile == 0 (L2 pads needles; haystack is
    a size-class array padded with the sort sentinel, which is fine: the
    sentinel is the dtype max, and real needles insert before it).
    """
    n = haystack.shape[0]
    m = needles.shape[0]
    assert m % tile == 0
    # Worst-case interval shrink per step is floor(size/2), so emptying a
    # width-n interval takes n.bit_length() steps (NOT ceil(log2 n): that
    # is one short and leaves a 1-wide interval unexamined).
    steps = max(1, n.bit_length())
    grid = (m // tile,)
    return pl.pallas_call(
        _searchsorted_kernel(side, steps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((tile,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((tile,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((m,), jnp.int32),
        interpret=INTERPRET,
    )(haystack, needles)
