"""Prefix-scan (accumulate) Pallas kernels.

The paper implements `accumulate` with Merrill & Garland's decoupled
look-back. Look-back's core trick — blocks spin on their predecessors'
published partial aggregates — needs forward-progress guarantees between
concurrently-resident blocks, which neither TPU's sequential grid nor
interpret mode provides. TPU adaptation (DESIGN.md §Hardware-Adaptation):
the classic three-phase block scan with the same O(n) work:

  phase 1 (L1, this file): per-tile inclusive scan in VMEM + tile sums;
  phase 2 (L2): exclusive scan of the (n/TILE,) tile sums — tiny;
  phase 3 (L1, this file): add each tile's carry to its lanes.

Supported ops: add (the SIHSort hot path), max, min.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import DEFAULT_TILE, INTERPRET

OPS = ("add", "max", "min")


def _scan_tile_kernel(op):
    def kernel(x_ref, scan_ref, sums_ref):
        v = x_ref[...]
        if op == "add":
            # dtype pinned: jnp.cumsum upcasts small ints under x64.
            s = jnp.cumsum(v, dtype=v.dtype)
        elif op == "max":
            s = jax.lax.cummax(v, axis=0)
        elif op == "min":
            s = jax.lax.cummin(v, axis=0)
        else:  # pragma: no cover - guarded by OPS
            raise ValueError(op)
        scan_ref[...] = s
        sums_ref[0] = s[-1]

    return kernel


def _carry_kernel(op):
    def kernel(scan_ref, carry_ref, out_ref):
        c = carry_ref[0]
        v = scan_ref[...]
        if op == "add":
            out_ref[...] = v + c
        elif op == "max":
            out_ref[...] = jnp.maximum(v, c)
        elif op == "min":
            out_ref[...] = jnp.minimum(v, c)
        else:  # pragma: no cover
            raise ValueError(op)

    return kernel


def scan_tiles(x, op: str = "add", *, tile: int = DEFAULT_TILE):
    """Phase 1: per-tile inclusive scan. Returns (tile_scans, tile_sums)."""
    assert op in OPS
    n = x.shape[0]
    assert n % tile == 0
    grid = (n // tile,)
    return pl.pallas_call(
        _scan_tile_kernel(op),
        grid=grid,
        in_specs=[pl.BlockSpec((tile,), lambda i: (i,))],
        out_specs=[
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), x.dtype),
            jax.ShapeDtypeStruct((n // tile,), x.dtype),
        ],
        interpret=INTERPRET,
    )(x)


def add_carries(tile_scans, carries, op: str = "add", *, tile: int = DEFAULT_TILE):
    """Phase 3: combine each tile's exclusive carry into its lanes."""
    assert op in OPS
    n = tile_scans.shape[0]
    assert n % tile == 0 and carries.shape[0] == n // tile
    grid = (n // tile,)
    return pl.pallas_call(
        _carry_kernel(op),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((tile,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), tile_scans.dtype),
        interpret=INTERPRET,
    )(tile_scans, carries)
