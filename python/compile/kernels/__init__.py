"""L1 Pallas kernels for the AcceleratedKernels reproduction.

Each module exposes a `pallas_call`-based kernel plus a thin functional
wrapper used by the L2 graphs in `compile.model`. All kernels run with
`interpret=True` so they lower to plain HLO ops executable on the CPU PJRT
client (real-TPU Mosaic lowering is compile-only in this environment — see
DESIGN.md §Hardware-Adaptation).
"""

from . import rbf, ljg, sort_tile, scan, reduce, searchsorted, ref  # noqa: F401
