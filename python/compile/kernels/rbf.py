"""Radial Basis Function arithmetic kernel (paper §III-A, Algorithm 4).

One Pallas grid step processes a `(3, TILE)` block of point coordinates
resident in VMEM and writes a `(TILE,)` block of RBF values:

    rbf[i] = exp(-1 / (1 - sqrt(x^2 + y^2 + z^2)))

This is the paper's "foreachindex over 100M points" recast as a
BlockSpec-tiled elementwise kernel: the HBM->VMEM block schedule plays the
role of the CUDA grid/block decomposition. Squares are written as plain
multiplications (the paper verifies compilers lower `^2` to `x*x`).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import DEFAULT_TILE, INTERPRET, ceil_div


def rbf_kernel(pts_ref, out_ref):
    x = pts_ref[0, :]
    y = pts_ref[1, :]
    z = pts_ref[2, :]
    r = jnp.sqrt(x * x + y * y + z * z)
    out_ref[...] = jnp.exp(-1.0 / (1.0 - r))


def rbf(points, *, tile: int = DEFAULT_TILE):
    """Apply the RBF kernel over a `(3, n)` coordinate array; n % tile == 0
    (the L2 wrapper pads). Returns `(n,)`."""
    n = points.shape[1]
    assert n % tile == 0, f"n={n} not a multiple of tile={tile}"
    grid = (ceil_div(n, tile),)
    return pl.pallas_call(
        rbf_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((3, tile), lambda i: (0, i))],
        out_specs=pl.BlockSpec((tile,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), points.dtype),
        interpret=INTERPRET,
    )(points)
