"""Reduction / mapreduce Pallas kernels.

The paper's `reduce` uses warp-shuffle trees inside blocks plus a global
pass; its `switch_below` argument finishes tiny tails on the host. TPU
adaptation: a per-tile vectorised partial reduce in VMEM (phase 1, here),
then the (n/TILE,) partials are folded at L2 — and the *rust* side of
`switch_below` (algorithms::reduce) can instead pull the partials back and
finish on the host when n is small, exactly the paper's device-sync
masking argument.

`mapreduce` fuses a named unary map into phase 1 so the mapped collection
is never materialised (paper §II-B). The map set is fixed at AOT time —
the transpiled-artifact analog of passing an arbitrary Julia lambda.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import DEFAULT_TILE, INTERPRET

OPS = ("add", "max", "min")

# Named unary maps available to `mapreduce` artifacts.
MAPS = {
    "identity": lambda v: v,
    "square": lambda v: v * v,
    "abs": lambda v: jnp.abs(v),
    "negate": lambda v: -v,
}


def _reduce_tile_kernel(op, map_name):
    f = MAPS[map_name]

    def kernel(x_ref, out_ref):
        v = f(x_ref[...])
        if op == "add":
            # dtype pinned: jnp.sum would upcast i16/i32 to i64 under x64.
            out_ref[0] = jnp.sum(v, dtype=v.dtype)
        elif op == "max":
            out_ref[0] = jnp.max(v)
        elif op == "min":
            out_ref[0] = jnp.min(v)
        else:  # pragma: no cover
            raise ValueError(op)

    return kernel


def reduce_tiles(x, op: str = "add", map_name: str = "identity",
                 *, tile: int = DEFAULT_TILE):
    """Phase 1: per-tile partial reduction. Returns (n/tile,) partials."""
    assert op in OPS and map_name in MAPS
    n = x.shape[0]
    assert n % tile == 0
    grid = (n // tile,)
    return pl.pallas_call(
        _reduce_tile_kernel(op, map_name),
        grid=grid,
        in_specs=[pl.BlockSpec((tile,), lambda i: (i,))],
        out_specs=pl.BlockSpec((1,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n // tile,), x.dtype),
        interpret=INTERPRET,
    )(x)
