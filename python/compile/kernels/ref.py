"""Pure-jnp oracles for every L1 kernel.

These are the correctness references: pytest (and hypothesis sweeps)
assert that each Pallas kernel matches its oracle to float tolerance
(bit-exact for integer ops). They deliberately use only `jax.numpy`
primitives — no Pallas — so a bug in the kernel machinery cannot hide in
the oracle.
"""

import jax.numpy as jnp


def rbf(points):
    """Radial Basis Function kernel (paper Algorithm 4).

    points: (3, n) array; returns (n,) with
    ``exp(-1 / (1 - sqrt(x^2 + y^2 + z^2)))``.
    """
    r = jnp.sqrt(points[0] ** 2 + points[1] ** 2 + points[2] ** 2)
    return jnp.exp(-1.0 / (1.0 - r))


def ljg(p1, p2, epsilon, sigma, r0, cutoff):
    """Lennard-Jones-Gauss potential (paper Algorithm 5).

    p1, p2: (3, n) atom position arrays. Pairwise potential between
    p1[:, i] and p2[:, i] with a cutoff branch:

        r  <  cutoff:  4*eps*((sigma/r)^12 - (sigma/r)^6)
                       - eps * exp(-(r - r0)^2 / (2*sigma^2))
        r  >= cutoff:  0

    Integer powers are expanded to multiplications (the optimisation the
    paper found GCC/Clang miss via ``powf`` but Julia performs).
    """
    dx = p1[0] - p2[0]
    dy = p1[1] - p2[1]
    dz = p1[2] - p2[2]
    r2 = dx * dx + dy * dy + dz * dz
    r = jnp.sqrt(r2)
    sr = sigma / r
    sr3 = sr * sr * sr
    sr6 = sr3 * sr3
    sr12 = sr6 * sr6
    lj = 4.0 * epsilon * (sr12 - sr6)
    gauss = epsilon * jnp.exp(-((r - r0) * (r - r0)) / (2.0 * sigma * sigma))
    u = lj - gauss
    return jnp.where(r < cutoff, u, jnp.zeros_like(u))


def sort(x):
    """Full-array ascending sort."""
    return jnp.sort(x)


def sort_pairs(keys, vals):
    """Key-value sort (stable on keys)."""
    order = jnp.argsort(keys, stable=True)
    return keys[order], vals[order]


def argsort(x):
    """Index permutation that sorts x (``sortperm``)."""
    return jnp.argsort(x, stable=True)


def cumsum_inclusive(x):
    return jnp.cumsum(x)


def cumsum_exclusive(x):
    return jnp.concatenate([jnp.zeros((1,), x.dtype), jnp.cumsum(x)[:-1]])


def reduce_sum(x):
    return jnp.sum(x)


def reduce_min(x):
    return jnp.min(x)


def reduce_max(x):
    return jnp.max(x)


def searchsorted_first(haystack, needles):
    """Leftmost insertion index (std::lower_bound / searchsortedfirst)."""
    return jnp.searchsorted(haystack, needles, side="left")


def searchsorted_last(haystack, needles):
    """Rightmost insertion index (std::upper_bound / searchsortedlast)."""
    return jnp.searchsorted(haystack, needles, side="right")
