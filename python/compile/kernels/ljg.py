"""Lennard-Jones-Gauss potential kernel (paper §III-B, Algorithm 5).

Pairwise LJG potential between two position arrays with a cutoff branch —
the paper's "difficult to predict branching if" that serialises GPU warps.
On TPU/Pallas the branch is expressed as a predicated `jnp.where` over the
whole VMEM tile (both sides computed, lanes select), which is exactly how
a warp-divergent branch executes on SIMT hardware anyway.

Constants (epsilon, sigma, r0, cutoff) enter as runtime scalar operands —
mirroring the paper, which passes them at runtime "so that constant
propagation cannot optimise them out". They ride in SMEM as a (4,) vector.

Integer powers are expanded to multiplications (pow3 = x*x*x;
pow6 = pow3*pow3; pow12 = pow6*pow6) — the transformation the paper found
Julia performs but `powf`-calling C compilers miss, costing C 5.7x on ARM.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import DEFAULT_TILE, INTERPRET, ceil_div


def ljg_kernel(p1_ref, p2_ref, consts_ref, out_ref):
    eps = consts_ref[0]
    sigma = consts_ref[1]
    r0 = consts_ref[2]
    cutoff = consts_ref[3]

    dx = p1_ref[0, :] - p2_ref[0, :]
    dy = p1_ref[1, :] - p2_ref[1, :]
    dz = p1_ref[2, :] - p2_ref[2, :]
    r2 = dx * dx + dy * dy + dz * dz
    r = jnp.sqrt(r2)

    sr = sigma / r
    sr3 = sr * sr * sr
    sr6 = sr3 * sr3
    sr12 = sr6 * sr6
    lj = 4.0 * eps * (sr12 - sr6)
    gauss = eps * jnp.exp(-((r - r0) * (r - r0)) / (2.0 * sigma * sigma))
    u = lj - gauss
    out_ref[...] = jnp.where(r < cutoff, u, jnp.zeros_like(u))


def ljg(p1, p2, consts, *, tile: int = DEFAULT_TILE):
    """LJG potential between `(3, n)` arrays `p1`, `p2`.

    `consts` is a `(4,)` array [epsilon, sigma, r0, cutoff] of the same
    dtype. Returns `(n,)`; n % tile == 0 (L2 pads).
    """
    n = p1.shape[1]
    assert p1.shape == p2.shape and n % tile == 0
    grid = (ceil_div(n, tile),)
    return pl.pallas_call(
        ljg_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((3, tile), lambda i: (0, i)),
            pl.BlockSpec((3, tile), lambda i: (0, i)),
            pl.BlockSpec((4,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((tile,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), p1.dtype),
        interpret=INTERPRET,
    )(p1, p2, consts)
