"""Shared helpers for the L1 Pallas kernels.

TPU adaptation notes (DESIGN.md §Hardware-Adaptation): the paper's
CUDA-style kernels use thread blocks + __shared__ tiles; here each Pallas
grid step owns a `(TILE,)` block resident in VMEM via `BlockSpec`, and the
per-thread logic is re-expressed as vectorised ops over the whole tile
(VPU lanes). `interpret=True` everywhere: the CPU PJRT client cannot run
Mosaic custom-calls, and interpret-mode lowers to plain HLO.
"""

import functools

import jax.numpy as jnp

# Default VMEM tile: 1024 elements is the paper's merge-sort block size and
# keeps (tile + bitonic scratch) far below the 16 MiB VMEM budget even for
# f64 key+value tiles (1024 * 8 B * 4 buffers = 32 KiB).
DEFAULT_TILE = 1024

# Interpret mode is mandatory on CPU PJRT (Mosaic custom-calls cannot run).
INTERPRET = True


def ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def next_pow2(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


@functools.lru_cache(maxsize=None)
def _log2(n: int) -> int:
    assert n & (n - 1) == 0 and n > 0, f"{n} is not a power of two"
    return n.bit_length() - 1


def sort_sentinel(dtype):
    """Order-preserving padding value: the maximum of the dtype, so padded
    lanes sink to the tail of an ascending sort and can be truncated."""
    dtype = jnp.dtype(dtype)
    if jnp.issubdtype(dtype, jnp.floating):
        return jnp.array(jnp.inf, dtype)
    return jnp.array(jnp.iinfo(dtype).max, dtype)


def bitonic_stages(n: int):
    """Yield the (k, j) compare-exchange stages of a full bitonic sort
    network over n (power-of-two) lanes, in execution order."""
    k = 2
    while k <= n:
        j = k // 2
        while j >= 1:
            yield k, j
            j //= 2
        k *= 2


def bitonic_merge_stages(n: int, start_k: int):
    """Stages with k >= start_k only — the *global* merge phases run at L2
    on tile-sorted data (tiles of size start_k are already sorted)."""
    k = start_k * 2
    while k <= n:
        j = k // 2
        while j >= 1:
            yield k, j
            j //= 2
        k *= 2


def compare_exchange(v, k: int, j: int, idx=None, dir_idx=None):
    """One vectorised bitonic compare-exchange stage over lanes of `v`.

    For each lane i with partner p = i ^ j: ascending iff (i & k) == 0
    (note (p & k) == (i & k) since j < k), the lower lane keeps the min of
    an ascending pair. Branch-free: a single where over min/max.

    `idx` indexes lanes *within this buffer* (partner gather); `dir_idx`
    supplies the direction bit and defaults to `idx`. They differ inside a
    tile kernel running the sub-network of a larger distributed sort: the
    partner is local but the alternating sort direction is a property of
    the *global* lane index (even tiles ascend, odd tiles descend) so the
    tile outputs seed the global merge stages correctly.
    """
    n = v.shape[0]
    if idx is None:
        idx = jnp.arange(n, dtype=jnp.int32)
    if dir_idx is None:
        dir_idx = idx
    partner = idx ^ j
    pv = v[partner]
    ascending = (dir_idx & k) == 0
    lower = idx < partner
    keep_min = lower == ascending
    return jnp.where(keep_min, jnp.minimum(v, pv), jnp.maximum(v, pv))


def compare_exchange_reshape(v, k: int, j: int):
    """Gather-free global compare-exchange stage (L2 optimisation).

    The xor-partner formulation lowers to a gather per stage — XLA-CPU
    executes those serially and the log²(n) stages dominated the whole
    sort. Reshaping to (n/2k, 2, k/2j, 2, j) exposes the partner as a
    *slice*: axis 1 is the direction bit (i & k), axis 3 separates the
    (i, i^j) pair. Everything lowers to copies + elementwise select,
    which XLA fuses; measured ~20x faster than the gather form at 2^17
    (EXPERIMENTS.md §Perf L2).
    """
    n = v.shape[0]
    assert j < k <= n
    if k == n:
        # Final merge stages: every lane ascends ((i & n) == 0 for i < n).
        v5 = v.reshape(1, 1, n // (2 * j), 2, j)
        lo = v5[:, :, :, 0, :]
        hi = v5[:, :, :, 1, :]
        mn = jnp.minimum(lo, hi)
        mx = jnp.maximum(lo, hi)
        return jnp.stack([mn, mx], axis=3).reshape(n)
    v5 = v.reshape(n // (2 * k), 2, k // (2 * j), 2, j)
    lo = v5[:, :, :, 0, :]
    hi = v5[:, :, :, 1, :]
    mn = jnp.minimum(lo, hi)
    mx = jnp.maximum(lo, hi)
    asc = jnp.stack([mn, mx], axis=3)
    desc = jnp.stack([mx, mn], axis=3)
    sel = (jnp.arange(2) == 0).reshape(1, 2, 1, 1, 1)
    return jnp.where(sel, asc, desc).reshape(n)


def compare_exchange_pairs_reshape(keys, vals, k: int, j: int):
    """Key-value variant of the reshape stage, with the same payload-index
    tie-break as `compare_exchange_pairs`."""
    n = keys.shape[0]
    assert j < k <= n
    shape = (1, 1, n // (2 * j), 2, j) if k == n else (n // (2 * k), 2, k // (2 * j), 2, j)
    k5 = keys.reshape(shape)
    v5 = vals.reshape(shape)
    ka, kb = k5[:, :, :, 0, :], k5[:, :, :, 1, :]
    va, vb = v5[:, :, :, 0, :], v5[:, :, :, 1, :]
    # Lexicographic (key, payload) order decides the swap.
    b_first = (kb < ka) | ((kb == ka) & (vb < va))
    mn_k = jnp.where(b_first, kb, ka)
    mx_k = jnp.where(b_first, ka, kb)
    mn_v = jnp.where(b_first, vb, va)
    mx_v = jnp.where(b_first, va, vb)
    if k == n:
        out_k = jnp.stack([mn_k, mx_k], axis=3).reshape(n)
        out_v = jnp.stack([mn_v, mx_v], axis=3).reshape(n)
        return out_k, out_v
    sel = (jnp.arange(2) == 0).reshape(1, 2, 1, 1, 1)
    out_k = jnp.where(sel, jnp.stack([mn_k, mx_k], axis=3), jnp.stack([mx_k, mn_k], axis=3))
    out_v = jnp.where(sel, jnp.stack([mn_v, mx_v], axis=3), jnp.stack([mx_v, mn_v], axis=3))
    return out_k.reshape(n), out_v.reshape(n)


def compare_exchange_pairs(keys, vals, k: int, j: int, idx=None, dir_idx=None):
    """Key-value variant: lanes swap keys and payloads together."""
    n = keys.shape[0]
    if idx is None:
        idx = jnp.arange(n, dtype=jnp.int32)
    if dir_idx is None:
        dir_idx = idx
    partner = idx ^ j
    pk = keys[partner]
    pv = vals[partner]
    ascending = (dir_idx & k) == 0
    lower = idx < partner
    keep_min = lower == ascending
    # Tie-break on the payload index so the pair sort is deterministic even
    # with duplicate keys (needed for sortperm reproducibility).
    take_self = jnp.where(
        keys == pk,
        (vals <= pv) == keep_min,
        (keys < pk) == keep_min,
    )
    nk = jnp.where(take_self, keys, pk)
    nv = jnp.where(take_self, vals, pv)
    return nk, nv
