"""AOT lowering: L2 JAX graphs -> artifacts/*.hlo.txt + manifest.json.

This is the "transpile once" half of the architecture: every
(op, dtype, size-class) variant is lowered to HLO **text** which the Rust
runtime (rust/src/runtime/) loads with `HloModuleProto::from_text_file`,
compiles on the PJRT CPU client, and executes from the L3 hot path.
Python never runs at request time.

Why text, not `.serialize()`: jax >= 0.5 emits HloModuleProto with 64-bit
instruction ids; the xla crate's xla_extension 0.5.1 rejects them
(`proto.id() <= INT_MAX`). The HLO text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Usage:
    python -m compile.aot [--out-dir ../artifacts] [--only REGEX] [--list]
                          [--force]

Incremental: an artifact is re-lowered only if its file is missing or
`--force` is given; the manifest is always rewritten to match reality.
"""

import argparse
import functools
import hashlib
import json
import os
import re
import sys
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

TILE = 1024

# Per-op tile overrides (the §Perf L1 pass, EXPERIMENTS.md): interpret-mode
# grid steps carry heavy per-step overhead on XLA-CPU, so ops whose VMEM
# working set allows it use far larger tiles than the 1024-lane default.
# Real-TPU budgets still hold: the largest working set is LJG at
# 2 x (3, 131072) f32 in + (131072,) out ~= 3.5 MiB << 16 MiB VMEM.
SORT_TILE = 4096        # full sort: 15 ms at 2^17 vs 168 ms at tile=1024
SCAN_TILE = 65536
REDUCE_TILE = 65536
ELEM_TILE = 131072      # rbf/ljg: 0.23 ms at 2^17 vs 31 ms at tile=1024

DTYPES = {
    "i16": jnp.int16,
    "i32": jnp.int32,
    "i64": jnp.int64,
    "f32": jnp.float32,
    "f64": jnp.float64,
}

SORT_DTYPES = ("i16", "i32", "i64", "f32", "f64")
NUM_DTYPES = ("i32", "i64", "f32", "f64")
FLOAT_DTYPES = ("f32", "f64")

SORT_CLASSES = (10, 14, 17)          # log2(n) size classes
PAIRS_CLASSES = (10, 14, 17)
SCAN_CLASSES = (14, 17, 20)
REDUCE_CLASSES = (14, 17, 20)
SEARCH_CLASSES = (10, 14, 17, 20)    # haystack sizes; needle block = TILE
ELEMWISE_CLASSES = (17, 20)
PRED_CLASSES = (14, 17)


def _spec(n2, dt):
    return jax.ShapeDtypeStruct((1 << n2,), DTYPES[dt])


def _io(shape, dt):
    return {"shape": list(shape), "dtype": dt}


def build_catalog():
    """The full artifact catalog: name -> (fn, arg_specs, inputs, outputs).

    Names are `{op}_{dtype}_n{log2n}` and are the contract with the Rust
    `runtime::registry` (see rust/src/runtime/registry.rs).
    """
    cat = {}

    def add(name, fn, specs, inputs, outputs, meta):
        assert name not in cat, name
        cat[name] = dict(fn=fn, specs=specs, inputs=inputs,
                         outputs=outputs, meta=meta)

    for dt in SORT_DTYPES:
        for n2 in SORT_CLASSES:
            n = 1 << n2
            add(f"sort_{dt}_n{n2}",
                functools.partial(model.merge_sort, tile=SORT_TILE),
                [_spec(n2, dt)],
                [_io((n,), dt)], [_io((n,), dt)],
                {"op": "sort", "dtype": dt, "n": n})
        for n2 in PAIRS_CLASSES:
            n = 1 << n2
            add(f"sort_pairs_{dt}_n{n2}",
                functools.partial(model.merge_sort_pairs, tile=SORT_TILE),
                [_spec(n2, dt), _spec(n2, "i32")],
                [_io((n,), dt), _io((n,), "i32")],
                [_io((n,), dt), _io((n,), "i32")],
                {"op": "sort_pairs", "dtype": dt, "n": n})

    for dt in NUM_DTYPES:
        for n2 in SCAN_CLASSES:
            n = 1 << n2
            add(f"scan_add_incl_{dt}_n{n2}",
                functools.partial(model.accumulate, op="add", inclusive=True, tile=SCAN_TILE),
                [_spec(n2, dt)], [_io((n,), dt)], [_io((n,), dt)],
                {"op": "scan_add_incl", "dtype": dt, "n": n})
            add(f"scan_add_excl_{dt}_n{n2}",
                functools.partial(model.accumulate, op="add", inclusive=False, tile=SCAN_TILE),
                [_spec(n2, dt)], [_io((n,), dt)], [_io((n,), dt)],
                {"op": "scan_add_excl", "dtype": dt, "n": n})
        for n2 in REDUCE_CLASSES:
            n = 1 << n2
            for op in ("add", "min", "max"):
                add(f"reduce_{op}_{dt}_n{n2}",
                    functools.partial(model.reduce, op=op, tile=REDUCE_TILE),
                    [_spec(n2, dt)], [_io((n,), dt)], [_io((), dt)],
                    {"op": f"reduce_{op}", "dtype": dt, "n": n})
        for n2 in (17, 20):
            n = 1 << n2
            add(f"reduce_partials_add_{dt}_n{n2}",
                functools.partial(model.reduce_partials, op="add", tile=REDUCE_TILE),
                [_spec(n2, dt)], [_io((n,), dt)],
                [_io((max(n // REDUCE_TILE, 1),), dt)],
                {"op": "reduce_partials_add", "dtype": dt, "n": n})

    for dt in FLOAT_DTYPES:
        n2 = 17
        n = 1 << n2
        add(f"mapreduce_sumsq_{dt}_n{n2}",
            functools.partial(model.reduce, op="add", map_name="square", tile=REDUCE_TILE),
            [_spec(n2, dt)], [_io((n,), dt)], [_io((), dt)],
            {"op": "mapreduce_sumsq", "dtype": dt, "n": n})

    for dt in SORT_DTYPES:
        for n2 in SEARCH_CLASSES:
            n = 1 << n2
            m = TILE
            for side in ("first", "last"):
                fn = (model.searchsorted_first if side == "first"
                      else model.searchsorted_last)
                add(f"searchsorted_{side}_{dt}_n{n2}", fn,
                    [_spec(n2, dt),
                     jax.ShapeDtypeStruct((m,), DTYPES[dt])],
                    [_io((n,), dt), _io((m,), dt)],
                    [_io((m,), "i32")],
                    {"op": f"searchsorted_{side}", "dtype": dt, "n": n,
                     "needles": m})

    for dt in FLOAT_DTYPES:
        for n2 in ELEMWISE_CLASSES:
            n = 1 << n2
            add(f"rbf_{dt}_n{n2}", functools.partial(model.rbf, tile=ELEM_TILE),
                [jax.ShapeDtypeStruct((3, n), DTYPES[dt])],
                [_io((3, n), dt)], [_io((n,), dt)],
                {"op": "rbf", "dtype": dt, "n": n})
            add(f"ljg_{dt}_n{n2}", functools.partial(model.ljg, tile=ELEM_TILE),
                [jax.ShapeDtypeStruct((3, n), DTYPES[dt]),
                 jax.ShapeDtypeStruct((3, n), DTYPES[dt]),
                 jax.ShapeDtypeStruct((4,), DTYPES[dt])],
                [_io((3, n), dt), _io((3, n), dt), _io((4,), dt)],
                [_io((n,), dt)],
                {"op": "ljg", "dtype": dt, "n": n})

    for dt in ("i32", "f32"):
        for n2 in PRED_CLASSES:
            n = 1 << n2
            add(f"any_gt_{dt}_n{n2}", functools.partial(model.any_gt, tile=REDUCE_TILE),
                [_spec(n2, dt), jax.ShapeDtypeStruct((), DTYPES[dt])],
                [_io((n,), dt), _io((), dt)], [_io((), "i32")],
                {"op": "any_gt", "dtype": dt, "n": n})
            add(f"all_gt_{dt}_n{n2}", functools.partial(model.all_gt, tile=REDUCE_TILE),
                [_spec(n2, dt), jax.ShapeDtypeStruct((), DTYPES[dt])],
                [_io((n,), dt), _io((), dt)], [_io((), "i32")],
                {"op": "all_gt", "dtype": dt, "n": n})

    return cat


def to_hlo_text(fn, specs) -> str:
    lowered = jax.jit(fn).lower(*specs)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out-dir", default=os.path.join(
        os.path.dirname(__file__), "..", "..", "artifacts"))
    p.add_argument("--only", default=None,
                   help="regex filter over artifact names")
    p.add_argument("--list", action="store_true")
    p.add_argument("--force", action="store_true")
    args = p.parse_args(argv)

    cat = build_catalog()
    names = sorted(cat)
    if args.only:
        rx = re.compile(args.only)
        names = [n for n in names if rx.search(n)]
    if args.list:
        for n in names:
            print(n)
        return 0

    out_dir = os.path.abspath(args.out_dir)
    os.makedirs(out_dir, exist_ok=True)

    manifest = {"version": 1, "tile": TILE, "artifacts": []}
    t_start = time.time()
    n_lowered = 0
    for i, name in enumerate(names):
        ent = cat[name]
        fname = f"{name}.hlo.txt"
        path = os.path.join(out_dir, fname)
        if args.force or not os.path.exists(path):
            t0 = time.time()
            text = to_hlo_text(ent["fn"], ent["specs"])
            tmp = path + ".tmp"
            with open(tmp, "w") as f:
                f.write(text)
            os.replace(tmp, path)
            n_lowered += 1
            print(f"[{i + 1}/{len(names)}] {name}: {len(text) / 1e3:.0f} kB "
                  f"in {time.time() - t0:.1f}s", flush=True)
        with open(path, "rb") as f:
            digest = hashlib.sha256(f.read()).hexdigest()[:16]
        manifest["artifacts"].append({
            "name": name,
            "file": fname,
            "sha256_16": digest,
            "inputs": ent["inputs"],
            "outputs": ent["outputs"],
            **ent["meta"],
        })

    man_path = os.path.join(out_dir, "manifest.json")
    with open(man_path, "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    print(f"manifest: {len(manifest['artifacts'])} artifacts "
          f"({n_lowered} lowered) in {time.time() - t_start:.1f}s "
          f"-> {man_path}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
