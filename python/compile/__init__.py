"""Build-time compile package (L1 Pallas kernels + L2 JAX graphs + AOT).

64-bit dtypes MUST be enabled before any jax import side effects: without
`jax_enable_x64`, jnp.int64/float64 silently degrade to 32-bit and every
i64/f64 artifact would be lowered with 4-byte parameters (the Rust runtime
would then reject the buffers at execute time).
"""

import jax

jax.config.update("jax_enable_x64", True)
