//! Quickstart: the AcceleratedKernels algorithm suite on every backend
//! through the unified `Session`/`Launch` API.
//!
//! Mirrors the paper's §II usage story: the *same* method call
//! dispatches to single-thread, multithreaded and transpiled-device
//! implementations, and per-call keywords (`block_size`, `max_tasks`,
//! `min_elems` — paper §III) ride in as a `Launch`.
//!
//! Run: `make artifacts && cargo run --release --example quickstart`

use accelkern::runtime::{Registry, Runtime};
use accelkern::session::{Launch, Session};
use accelkern::util::Prng;
use accelkern::workload::{generate, points_f32, Distribution};

fn main() -> anyhow::Result<()> {
    let mut rng = Prng::new(42);
    let xs: Vec<i32> = generate(&mut rng, Distribution::Uniform, 200_000);

    // Pick sessions: host ones always work; the device session needs
    // `make artifacts` (falls back gracefully if missing).
    let mut sessions = vec![Session::native(), Session::threaded(4)];
    match Runtime::open_default() {
        Ok(rt) => {
            println!("device platform: {}", rt.platform());
            sessions.push(Session::device(Registry::new(rt)));
        }
        Err(e) => println!("(no device artifacts: {e}; host sessions only)"),
    }

    // Per-call tuning knobs (the paper's keyword arguments): cap the
    // worker count, keep small inputs sequential, reuse merge scratch.
    let tuned = Launch::new().max_tasks(4).min_elems_per_task(16 * 1024).reuse_scratch(true);

    for s in &sessions {
        println!("\n== session: {} ==", s.name());

        // merge_sort — default policy, then with explicit knobs.
        let mut v = xs.clone();
        s.sort(&mut v, None)?;
        println!("sort:             first={} last={}", v[0], v[v.len() - 1]);
        let mut w = xs.clone();
        s.sort(&mut w, Some(&tuned))?;
        assert_eq!(v, w); // knobs change scheduling, never results

        // sortperm — index permutation that sorts xs
        let perm = s.sortperm(&xs, None)?;
        println!("sortperm:         xs[perm[0]]={} (global min)", xs[perm[0] as usize]);

        // reduce / mapreduce (switch_below is a Launch knob now)
        let total = s.reduce(&xs, accelkern::algorithms::ReduceKind::Add,
                             Some(&Launch::new().switch_below(4096)))?;
        let maxsq = s.mapreduce(
            &xs,
            |x: i32| x.wrapping_mul(x),
            accelkern::algorithms::ReduceKind::Max,
            None,
        )?;
        println!("reduce add:       {total}");
        println!("mapreduce max x²: {maxsq}");

        // accumulate (prefix scan)
        let scans = s.accumulate(&xs[..8], true, None)?;
        println!("accumulate[..8]:  {scans:?}");

        // searchsorted
        let needles = [v[0], v[v.len() / 2], v[v.len() - 1]];
        let idx = s.searchsorted_first(&v, &needles, None)?;
        println!("searchsorted:     {idx:?}");

        // any / all with early exit — generic over dtypes now
        let fs: Vec<f32> = (0..100_000).map(|i| i as f32 / 1e5).collect();
        println!(
            "any > 0.9999: {}   all > -1: {}   any i32 > 0: {}",
            s.any_gt(&fs, 0.9999f32, None)?,
            s.all_gt(&fs, -1.0f32, None)?,
            s.any_gt(&xs, 0i32, None)?,
        );

        // foreachindex — the paper's Algorithm 3 copy kernel
        let src: Vec<i32> = (0..1000).collect();
        let mut dst = vec![0i32; 1000];
        s.foreach_mut(&mut dst, |i, d| *d = src[i], None);
        assert_eq!(dst, src);
        println!("foreachindex:     copy kernel OK");

        // Table II arithmetic kernels
        let pts = points_f32(&mut Prng::new(7), 10_000);
        let r = s.rbf(&pts, None)?;
        println!("rbf[0..3]:        {:?}", &r[..3]);

        // The metrics sink every session carries.
        println!(
            "metrics:          {} calls, {} elems, scratch {}h/{}m",
            s.metrics().calls(),
            s.metrics().elems(),
            s.metrics().scratch_hits(),
            s.metrics().scratch_misses(),
        );
    }
    println!("\nquickstart OK");
    Ok(())
}
