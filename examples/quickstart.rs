//! Quickstart: the AcceleratedKernels algorithm suite on every backend.
//!
//! Mirrors the paper's §II usage story: the *same* API call dispatches to
//! single-thread, multithreaded and transpiled-device implementations.
//!
//! Run: `make artifacts && cargo run --release --example quickstart`

use accelkern::algorithms as ak;
use accelkern::backend::Backend;
use accelkern::runtime::{Registry, Runtime};
use accelkern::util::Prng;
use accelkern::workload::{generate, points_f32, Distribution};

fn main() -> anyhow::Result<()> {
    let mut rng = Prng::new(42);
    let xs: Vec<i32> = generate(&mut rng, Distribution::Uniform, 200_000);

    // Pick backends: host ones always work; the device backend needs
    // `make artifacts` (falls back gracefully if missing).
    let mut backends = vec![Backend::Native, Backend::Threaded(4)];
    match Runtime::open_default() {
        Ok(rt) => {
            println!("device platform: {}", rt.platform());
            backends.push(Backend::device(Registry::new(rt)));
        }
        Err(e) => println!("(no device artifacts: {e}; host backends only)"),
    }

    for backend in &backends {
        println!("\n== backend: {} ==", backend.name());

        // merge_sort
        let mut v = xs.clone();
        ak::sort(backend, &mut v)?;
        println!("sort:             first={} last={}", v[0], v[v.len() - 1]);

        // sortperm — index permutation that sorts xs
        let perm = ak::sortperm(backend, &xs)?;
        println!("sortperm:         xs[perm[0]]={} (global min)", xs[perm[0] as usize]);

        // reduce / mapreduce
        let total = ak::reduce(backend, &xs, ak::ReduceKind::Add, 4096)?;
        let maxsq = ak::mapreduce(backend, &xs, |x: i32| x.wrapping_mul(x), ak::ReduceKind::Max)?;
        println!("reduce add:       {total}");
        println!("mapreduce max x²: {maxsq}");

        // accumulate (prefix scan)
        let scans = ak::accumulate(backend, &xs[..8], true)?;
        println!("accumulate[..8]:  {scans:?}");

        // searchsorted
        let needles = [v[0], v[v.len() / 2], v[v.len() - 1]];
        let idx = ak::searchsorted_first(backend, &v, &needles)?;
        println!("searchsorted:     {idx:?}");

        // any / all with early exit
        let fs: Vec<f32> = (0..100_000).map(|i| i as f32 / 1e5).collect();
        println!(
            "any > 0.9999: {}   all > -1: {}",
            ak::any_gt(backend, &fs, 0.9999)?,
            ak::all_gt(backend, &fs, -1.0)?
        );

        // foreachindex — the paper's Algorithm 3 copy kernel
        let src: Vec<i32> = (0..1000).collect();
        let mut dst = vec![0i32; 1000];
        ak::foreach::foreach_mut(backend, &mut dst, |i, d| *d = src[i]);
        assert_eq!(dst, src);
        println!("foreachindex:     copy kernel OK");

        // Table II arithmetic kernels
        let pts = points_f32(&mut Prng::new(7), 10_000);
        let r = ak::rbf(backend, &pts)?;
        println!("rbf[0..3]:        {:?}", &r[..3]);
    }
    println!("\nquickstart OK");
    Ok(())
}
