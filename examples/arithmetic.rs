//! Table II arithmetic kernels, interactive scale.
//!
//! Reproduces the paper's §III benchmark at a configurable element count:
//! RBF and LJG over the implementation matrix (1-thread expanded,
//! 1-thread powf "naive C", N-thread, device artifact), with mean ±σ rows
//! like Table II and the powf-pathology ratio from §III-B.
//!
//! Run: `cargo run --release --example arithmetic [n] [threads]`

use accelkern::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let n: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(1 << 21);
    let threads: usize = args
        .get(2)
        .and_then(|s| s.parse().ok())
        .unwrap_or_else(accelkern::backend::threaded::default_threads);
    let rt = Runtime::open_default().ok();
    if rt.is_none() {
        eprintln!("(no artifacts; device rows skipped — run `make artifacts`)");
    }
    accelkern::coordinator::campaign::table2(n, threads, &rt, false)
}
