//! CPU–GPU co-sorting (the paper's §IV-A composability highlight), on
//! two levels of the stack:
//!
//! 1. **Inside one rank** — `hybrid::co_sort` splits a single shard
//!    between the host thread pool and the device engine using a
//!    calibrated, cost-model-driven `HybridPlan`, sorts both halves
//!    concurrently and k-way merges (DESIGN.md §10).
//! 2. **Across ranks** — heterogeneous SIHSort: CPU ranks, vendor-analog
//!    device ranks and HY hybrid ranks all participate in the *same*
//!    collective sort — no special-casing in either library, exactly the
//!    MPISort.jl + AK + Thrust story.
//!
//! Run: `cargo run --release --example cosort`

use std::time::Instant;

use accelkern::backend::Backend;
use accelkern::cfg::{RunConfig, Sorter};
use accelkern::cluster::DeviceModel;
use accelkern::coordinator::driver::run_distributed_sort_mixed;
use accelkern::hybrid::{calibrate_sort, HybridEngine, HybridPlan};
use accelkern::runtime::{Registry, Runtime};
use accelkern::util::{fmt_throughput, Prng};
use accelkern::workload::{generate, Distribution};

fn main() -> anyhow::Result<()> {
    let rt = Runtime::open_default().ok();
    if rt.is_none() {
        println!("(no artifacts: device engines degrade to their host stand-ins)");
    }
    let device_backend = rt.clone().map(|rt| Backend::device(Registry::new(rt)));
    let host_threads = accelkern::backend::threaded::default_threads();

    // ---- Level 1: one shard, two engines at once ---------------------------
    let dev_ops = device_backend.as_ref().and_then(|b| b.device_ops());
    let cal = calibrate_sort::<i64>(1 << 17, host_threads, dev_ops)?;
    let dm = DeviceModel::default();
    // Split real work for the engines as they actually execute; the
    // device-model projection is reported alongside for context.
    let plan = cal.plan_measured(1.0);
    println!(
        "calibration: host {:.2} Melem/s, executing device engine {:.2} Melem/s \
         -> {:.1}% host split (model-projected: {:.1}%, cost-aware x22: {:.1}%)",
        cal.host_elems_per_sec / 1e6,
        cal.executing_device_throughput() / 1e6,
        plan.host_fraction * 100.0,
        cal.plan(&dm, 1.0).host_fraction * 100.0,
        cal.plan(&dm, 22.0).host_fraction * 100.0,
    );

    let n = 1 << 21;
    let xs: Vec<i64> = generate(&mut Prng::new(42), Distribution::Uniform, n);
    for (label, eng) in [
        ("host-only      ", HybridEngine::new(HybridPlan::host_only(), host_threads, None)),
        (
            "hybrid (calib.)",
            HybridEngine::from_backends(plan, host_threads, device_backend.clone()),
        ),
        (
            "hybrid (50/50) ",
            HybridEngine::from_backends(HybridPlan::new(0.5), host_threads, device_backend.clone()),
        ),
    ] {
        // One unified call: `Session::hybrid(...).sort` dispatches to
        // `hybrid::co_sort` — both engines sort concurrently.
        let session = accelkern::session::Session::hybrid(eng);
        let mut buf = xs.clone();
        let t0 = Instant::now();
        session.sort(&mut buf, None)?;
        let secs = t0.elapsed().as_secs_f64();
        println!(
            "  {label}  {n} i64 in {:.1} ms  ({})",
            secs * 1e3,
            fmt_throughput(8.0 * n as f64 / secs)
        );
        assert!(accelkern::dtype::is_sorted_total(&buf));
    }

    // ---- Level 2: heterogeneous collective sort ----------------------------
    let mut cfg = RunConfig::default();
    cfg.ranks = 8;
    cfg.elems_per_rank = 250_000;
    cfg.dtype = accelkern::dtype::ElemType::I64;

    // Two CPU ranks, four device ranks, two hybrid co-sorting ranks — one
    // heterogeneous collective sort.
    let sorters = vec![
        Sorter::JuliaBase,
        Sorter::Ak,
        Sorter::ThrustMerge,
        Sorter::ThrustRadix,
        Sorter::Hybrid,
        Sorter::Ak,
        Sorter::ThrustRadix,
        Sorter::Hybrid,
    ];
    println!(
        "\nco-sorting with per-rank engines: {:?}",
        sorters.iter().map(|s| s.code()).collect::<Vec<_>>()
    );
    let out = run_distributed_sort_mixed::<i64>(&cfg, &sorters, rt.clone())?;
    println!("mixed-engine run:\n  {}", out.record.row());

    // Same workload, homogeneous AK, for comparison: results must agree
    // in sizes (identical splitters modulo sampling noise is not
    // guaranteed, but global order and conservation are verified inside).
    cfg.sorter = Sorter::Ak;
    let homo = run_distributed_sort_mixed::<i64>(&cfg, &vec![Sorter::Ak; 8], rt)?;
    println!("homogeneous AK run:\n  {}", homo.record.row());

    println!(
        "\nthroughputs: mixed {} vs homogeneous {}",
        fmt_throughput(out.record.throughput_bps()),
        fmt_throughput(homo.record.throughput_bps()),
    );
    println!("co-sort OK: CPU, device and hybrid ranks composed in one collective sort");
    Ok(())
}
