//! CPU–GPU co-sorting (the paper's §IV-A composability highlight):
//! CPU ranks running the Julia-Base-analog sorter participate in the
//! *same* collective SIHSort as device ranks running the AK artifact and
//! the vendor-primitive analogs — no special-casing in either library,
//! exactly the MPISort.jl + AK + Thrust story.
//!
//! Run: `cargo run --release --example cosort`

use accelkern::cfg::{RunConfig, Sorter};
use accelkern::coordinator::driver::run_distributed_sort_mixed;
use accelkern::runtime::Runtime;
use accelkern::util::fmt_throughput;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::open_default().ok();
    if rt.is_none() {
        println!("(no artifacts: AK ranks degrade to host path)");
    }

    let mut cfg = RunConfig::default();
    cfg.ranks = 8;
    cfg.elems_per_rank = 250_000;
    cfg.dtype = accelkern::dtype::ElemType::I64;

    // Two CPU ranks, six device ranks with three different local sorters —
    // one heterogeneous collective sort.
    let sorters = vec![
        Sorter::JuliaBase,
        Sorter::Ak,
        Sorter::ThrustMerge,
        Sorter::ThrustRadix,
        Sorter::JuliaBase,
        Sorter::Ak,
        Sorter::ThrustMerge,
        Sorter::ThrustRadix,
    ];
    println!("co-sorting with per-rank engines: {:?}", sorters.iter().map(|s| s.code()).collect::<Vec<_>>());

    let out = run_distributed_sort_mixed::<i64>(&cfg, &sorters, rt.clone())?;
    println!("\nmixed-engine run:\n  {}", out.record.row());

    // Same workload, homogeneous AK, for comparison: results must agree
    // in sizes (identical splitters modulo sampling noise is not
    // guaranteed, but global order and conservation are verified inside).
    cfg.sorter = Sorter::Ak;
    let homo = run_distributed_sort_mixed::<i64>(&cfg, &vec![Sorter::Ak; 8], rt)?;
    println!("homogeneous AK run:\n  {}", homo.record.row());

    println!(
        "\nthroughputs: mixed {} vs homogeneous {}",
        fmt_throughput(out.record.throughput_bps()),
        fmt_throughput(homo.record.throughput_bps()),
    );
    println!("co-sort OK: CPU and device ranks composed in one collective sort");
    Ok(())
}
