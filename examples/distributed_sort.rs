//! End-to-end driver (the headline validation run, DESIGN.md §5):
//! a multi-rank distributed sort on the simulated Baskerville cluster,
//! exercising every layer at once — workload generation, the SIHSort
//! coordinator, the MPI-like fabric with the NVLink-vs-staged link model,
//! rank-local sorting through the Pallas/XLA artifact (AK), and the
//! metrics stack. Prints the paper-style record, the phase breakdown,
//! and the NVLink speedup for the same workload.
//!
//! Run: `make artifacts && cargo run --release --example distributed_sort
//!       [-- --ranks 16 --mb-per-rank 4 --dtype i32 --sorter AK]`

use accelkern::cfg::TransferMode;
use accelkern::cli::Cli;
use accelkern::coordinator::driver::run_for_config;
use accelkern::runtime::Runtime;
use accelkern::util::{fmt_bytes, fmt_throughput};

fn main() -> anyhow::Result<()> {
    // Reuse the CLI flag parser with a synthetic subcommand.
    let args = std::iter::once("distributed_sort".to_string())
        .chain(std::iter::once("run".to_string()))
        .chain(std::env::args().skip(1))
        .collect::<Vec<_>>();
    let cli = Cli::parse(args)?;
    let mut cfg = cli.run_config()?;
    if !cli.has("ranks") {
        cfg.ranks = 16; // 4 simulated Baskerville trays
    }
    if !cli.has("elems-per-rank") && !cli.has("mb-per-rank") {
        cfg.elems_per_rank = 1 << 20; // 4 MB/rank of i32
    }

    let rt = match Runtime::open_default() {
        Ok(rt) => {
            println!("device runtime: {} ({} artifacts)", rt.platform(), rt.manifest().artifacts.len());
            Some(rt)
        }
        Err(e) => {
            println!("no device runtime ({e}); AK degrades to host path");
            None
        }
    };

    println!(
        "\nsorting {} across {} simulated ranks ({} per rank, dtype {}, sorter {:?})",
        fmt_bytes(cfg.total_bytes() as f64),
        cfg.ranks,
        fmt_bytes((cfg.elems_per_rank * cfg.dtype.size_bytes()) as f64),
        cfg.dtype,
        cfg.sorter,
    );

    // NVLink (GPUDirect) run.
    cfg.transfer = TransferMode::GpuDirect;
    let direct = run_for_config(&cfg, rt.clone())?;
    println!("\nNVLink transfer:\n  {}", direct.record.row());

    // Host-staged run of the identical workload.
    cfg.transfer = TransferMode::CpuStaged;
    let staged = run_for_config(&cfg, rt)?;
    println!("CPU-staged transfer:\n  {}", staged.record.row());

    let speedup = staged.record.sim_total / direct.record.sim_total;
    println!(
        "\nNVLink end-to-end speedup: {speedup:.2}x (paper: 4.93x mean across its grid)"
    );
    println!(
        "throughput (NVLink): {}   bucket sizes {}..{} (ideal {})",
        fmt_throughput(direct.record.throughput_bps()),
        direct.out_sizes.iter().min().unwrap(),
        direct.out_sizes.iter().max().unwrap(),
        cfg.elems_per_rank,
    );
    println!("verification: global order + element conservation checked ✔");
    Ok(())
}
